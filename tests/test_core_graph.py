"""Unit tests for the VR-PRUNE MoC core: graph, analyzer, simulator,
synthesis, explorer."""
import numpy as np
import pytest

from repro.core import (Actor, ActorType, Dpg, Graph, Mapping, Port, PortDir,
                        PlatformGraph, PlatformModel, ProcessingUnit, Link,
                        Simulator, analyze, compile_local_step,
                        repetition_vector, synthesize, Explorer)
from repro.core.synthesis import read_mapping_file, write_mapping_file


def _spa(name, n_in=1, n_out=1, fn=None, shape=(4,), rate=1):
    inp = [Port(f"in{i}" if n_in > 1 else "in", PortDir.IN, rate, rate,
                token_shape=shape) for i in range(n_in)]
    out = [Port(f"out{i}" if n_out > 1 else "out", PortDir.OUT, rate, rate,
                token_shape=shape) for i in range(n_out)]

    def fire(inputs, state, atr):
        toks = [t for v in inputs.values() for t in v if t is not None]
        val = fn(toks) if fn else (toks[0] if toks else np.zeros(shape, np.float32))
        return {p.name: [val] * atr[p.name] for p in out}, state

    return Actor(name, ActorType.SPA, inp, out, fire_fn=fire)


def _source(name, shape=(4,)):
    out = [Port("out", PortDir.OUT, token_shape=shape)]

    def fire(inputs, state, atr):
        feed = inputs.get("__feed__")
        tok = feed[0] if feed else np.ones(shape, np.float32)
        return {"out": [tok]}, state

    return Actor(name, ActorType.SPA, [], out, fire_fn=fire)


def _sink(name, shape=(4,)):
    inp = [Port("in", PortDir.IN, token_shape=shape)]

    def fire(inputs, state, atr):
        return {"result": list(inputs["in"])}, state

    return Actor(name, ActorType.SPA, inp, [], fire_fn=fire)


def chain_graph(n_mid=3, shape=(4,)):
    g = Graph("chain")
    prev = g.add_actor(_source("src", shape))
    for i in range(n_mid):
        a = g.add_actor(_spa(f"a{i}", fn=lambda ts: ts[0] + 1.0, shape=shape))
        g.connect(prev.port("out"), a.port("in"))
        prev = a
    snk = g.add_actor(_sink("snk", shape))
    g.connect(prev.port("out"), snk.port("in"))
    return g


class TestGraphStructure:
    def test_ports_attached_and_lookup(self):
        g = chain_graph()
        assert g.actors["a0"].port("in").actor.name == "a0"
        with pytest.raises(KeyError):
            g.actors["a0"].port("nope")

    def test_duplicate_actor_rejected(self):
        g = chain_graph()
        with pytest.raises(ValueError, match="duplicate"):
            g.add_actor(_spa("a0"))

    def test_token_type_mismatch_rejected(self):
        g = Graph("t")
        a = g.add_actor(_source("s", (4,)))
        b = g.add_actor(_sink("k", (8,)))
        with pytest.raises(ValueError, match="mismatch"):
            g.connect(a.port("out"), b.port("in"))

    def test_spa_with_variable_rate_rejected(self):
        with pytest.raises(ValueError, match="variable-rate"):
            Actor("bad", ActorType.SPA,
                  [Port("in", PortDir.IN, lrl=1, url=4, token_shape=(2,))], [])

    def test_topo_order_and_precedence(self):
        g = chain_graph(3)
        order = [a.name for a in g.topo_order()]
        assert order == ["src", "a0", "a1", "a2", "snk"]
        prec = g.precedence_index()
        assert prec["src"] == 0 and prec["snk"] == 4

    def test_zero_delay_cycle_detected_in_topo(self):
        g = Graph("cyc")
        a = g.add_actor(_spa("a"))
        b = g.add_actor(_spa("b"))
        g.connect(a.port("out"), b.port("in"))
        g.connect(b.port("out"), a.port("in"))
        with pytest.raises(ValueError, match="cycle"):
            g.topo_order()

    def test_token_bytes(self):
        p = Port("x", PortDir.OUT, token_shape=(24, 24, 32), token_dtype="float32")
        assert p.token_bytes == 73728  # the paper's L2->L3 token (Fig 2)


class TestAnalyzer:
    def test_valid_chain_passes(self):
        rep = analyze(chain_graph())
        assert rep.ok, rep.errors
        assert set(rep.repetition_vector.values()) == {1}

    def test_multirate_repetition_vector(self):
        # src produces 2 tokens/firing, sink consumes 3 -> q = (3, 2)
        g = Graph("mr")
        out = [Port("out", PortDir.OUT, 2, 2, token_shape=(1,))]
        a = g.add_actor(Actor(
            "p", ActorType.SPA, [], out,
            fire_fn=lambda i, s, r: ({"out": [np.zeros(1)] * 2}, s)))
        inp = [Port("in", PortDir.IN, 3, 3, token_shape=(1,))]
        b = g.add_actor(Actor("c", ActorType.SPA, inp, [],
                              fire_fn=lambda i, s, r: ({}, s)))
        g.connect(a.port("out"), b.port("in"), capacity=6)
        rv = repetition_vector(g)
        assert rv == {"p": 3, "c": 2}

    def test_inconsistent_graph_rejected(self):
        # Two paths with incompatible rate products -> unbalanceable.
        g = Graph("bad")
        s = g.add_actor(Actor(
            "s", ActorType.SPA, [],
            [Port("out0", PortDir.OUT, 1, 1, token_shape=(1,)),
             Port("out1", PortDir.OUT, 2, 2, token_shape=(1,))],
            fire_fn=lambda i, st, r: ({"out0": [0], "out1": [0, 0]}, st)))
        t = g.add_actor(Actor(
            "t", ActorType.SPA,
            [Port("in0", PortDir.IN, 1, 1, token_shape=(1,)),
             Port("in1", PortDir.IN, 1, 1, token_shape=(1,))], [],
            fire_fn=lambda i, st, r: ({}, st)))
        g.connect(s.port("out0"), t.port("in0"))
        g.connect(s.port("out1"), t.port("in1"))
        rep = analyze(g)
        assert not rep.ok
        assert any("inconsistent" in e for e in rep.errors)

    def test_deadlock_cycle_without_delay(self):
        g = Graph("dead")
        a = g.add_actor(_spa("a"))
        b = g.add_actor(_spa("b"))
        g.connect(a.port("out"), b.port("in"))
        g.connect(b.port("out"), a.port("in"))
        rep = analyze(g)
        assert not rep.ok
        assert any("deadlock" in e for e in rep.errors)

    def test_cycle_with_delay_tokens_ok(self):
        g = Graph("fb")
        a = g.add_actor(_spa("a"))
        b = g.add_actor(_spa("b"))
        g.connect(a.port("out"), b.port("in"))
        g.connect(b.port("out"), a.port("in"), delay_tokens=1)
        rep = analyze(g)
        assert rep.ok, rep.errors

    def test_buffer_overflow_detected(self):
        g = Graph("ovf")
        out = [Port("out", PortDir.OUT, 4, 4, token_shape=(1,))]
        a = g.add_actor(Actor(
            "p", ActorType.SPA, [], out,
            fire_fn=lambda i, s, r: ({"out": [0] * 4}, s)))
        inp = [Port("in", PortDir.IN, 1, 1, token_shape=(1,))]
        b = g.add_actor(Actor("c", ActorType.SPA, inp, [],
                              fire_fn=lambda i, s, r: ({}, s)))
        g.connect(a.port("out"), b.port("in"), capacity=2)  # needs >= 4
        rep = analyze(g)
        assert not rep.ok
        assert any("overflow" in e for e in rep.errors)

    def test_dynamic_actor_outside_dpg_rejected(self):
        g = chain_graph()
        g.actors["a1"].actor_type = ActorType.DPA
        rep = analyze(g)
        assert not rep.ok
        assert any("outside any DPG" in e for e in rep.errors)

    def test_dpg_composition_rule(self):
        # A DPG must have exactly 1 CA and 2 DAs.
        g = chain_graph(3)
        for n in ("a0", "a1", "a2"):
            g.actors[n].dpg = "d"
        g.actors["a0"].actor_type = ActorType.DA
        g.actors["a2"].actor_type = ActorType.DA
        g.actors["a1"].actor_type = ActorType.DPA
        g.dpgs["d"] = Dpg("d", ca="missing", entry_da="a0", exit_da="a2",
                          members=["a0", "a1", "a2"])
        rep = analyze(g)
        assert not rep.ok
        assert any("exactly 1 CA" in e for e in rep.errors)


class TestSimulator:
    def test_chain_semantics(self):
        g = chain_graph(3)
        sim = Simulator(g)
        res = sim.run(5)
        assert len(res.outputs["snk"]) == 5
        np.testing.assert_allclose(res.outputs["snk"][0],
                                   np.ones(4, np.float32) + 3.0)

    def test_source_feed(self):
        g = chain_graph(1)
        feeds = [np.full((4,), float(i), np.float32) for i in range(3)]
        res = Simulator(g).run(3, source_inputs={"src": feeds})
        for i, out in enumerate(res.outputs["snk"]):
            np.testing.assert_allclose(out, feeds[i] + 1.0)

    def test_bounded_fifo_backpressure(self):
        # capacity-1 fifo still completes (firing rule includes space check)
        g = Graph("bp")
        s = g.add_actor(_source("s"))
        a = g.add_actor(_spa("a", fn=lambda ts: ts[0]))
        k = g.add_actor(_sink("k"))
        g.connect(s.port("out"), a.port("in"), capacity=1)
        g.connect(a.port("out"), k.port("in"), capacity=1)
        res = Simulator(g).run(10)
        assert len(res.outputs["k"]) == 10

    def test_variable_rate_symmetric_requirement_enforced(self):
        # A DPA that produces fewer tokens than atr must be rejected.
        g = Graph("vr")
        s = g.add_actor(_source("s", (1,)))
        inp = [Port("in", PortDir.IN, 1, 1, token_shape=(1,))]
        out = [Port("out", PortDir.OUT, 1, 2, token_shape=(1,))]

        def bad_fire(i, st, r):
            return {"out": [np.zeros(1)] * (r["out"] - 1)}, st  # too few!

        d = g.add_actor(Actor("d", ActorType.DPA, inp, out, fire_fn=bad_fire,
                              dpg="x"))
        kin = [Port("in", PortDir.IN, 1, 2, token_shape=(1,))]
        k = g.add_actor(Actor("k", ActorType.DPA, kin, [],
                              fire_fn=lambda i, st, r: ({}, st), dpg="x"))
        g.connect(s.port("out"), d.port("in"))
        g.connect(d.port("out"), k.port("in"), capacity=4)
        sim = Simulator(g, atr_fn=lambda a, i: {"out": 2} if a.name == "d" else {})
        with pytest.raises(ValueError, match="symmetric token rate"):
            sim.run(1)

    def test_modeled_clocks_with_platform(self):
        g = chain_graph(2)
        g.actors["a0"].cost_flops = 1e9
        g.actors["a1"].cost_flops = 2e9
        pg = PlatformGraph("p")
        pg.add_unit(ProcessingUnit("endpoint", flops=1e9))
        pg.add_unit(ProcessingUnit("server", flops=2e9))
        pg.add_link(Link("endpoint", "server", bandwidth=1e6))
        m = Mapping("m", {"src": "endpoint", "a0": "endpoint",
                          "a1": "server", "snk": "server"}, pg)
        res = Simulator(g, mapping=m, platform=PlatformModel(pg)).run(1)
        assert res.unit_busy_s["endpoint"] == pytest.approx(1.0)
        assert res.unit_busy_s["server"] == pytest.approx(1.0)
        # one 16-byte token crossed the boundary
        assert sum(res.link_busy_s.values()) == pytest.approx(16 / 1e6)


class TestSynthesis:
    def test_split_and_channels(self):
        g = chain_graph(3)
        m = Mapping("m", {"src": "ep", "a0": "ep", "a1": "sv", "a2": "sv",
                          "snk": "sv"})
        prog = synthesize(g, m)
        assert [s.unit for s in prog.stages] == ["ep", "sv"]
        assert len(prog.channels) == 1
        ch = prog.channels[0]
        assert (ch.src_actor, ch.dst_actor) == ("a0", "a1")
        assert ch.token_bytes == 16

    def test_run_local_matches_simulator(self):
        g = chain_graph(4)
        m = Mapping("m", {"src": "ep", "a0": "ep", "a1": "sv", "a2": "sv",
                          "a3": "sv", "snk": "sv"})
        prog = synthesize(g, m)
        feed = np.arange(4, dtype=np.float32)
        out_staged = prog.run_local({"src": feed})
        out_sim = Simulator(g).run(1, source_inputs={"src": [feed]})
        np.testing.assert_allclose(out_staged["snk"][0],
                                   out_sim.outputs["snk"][0])

    def test_tx_rx_insertion_is_transparent(self):
        """Sec III.B: distribution requires no changes to the app graph —
        every partition point yields identical results."""
        g = chain_graph(4)
        feed = np.arange(4, dtype=np.float32)
        ref = None
        for pp in range(1, 7):
            m = Mapping.partition_point(g, pp, endpoint="ep", server="sv")
            out = synthesize(g, m).run_local({"src": feed})["snk"][0]
            if ref is None:
                ref = out
            np.testing.assert_allclose(out, ref)

    def test_mapping_file_roundtrip(self, tmp_path):
        g = chain_graph(2)
        m = Mapping.partition_point(g, 2, endpoint="ep", server="sv")
        p = str(tmp_path / "m.json")
        write_mapping_file(p, m, local_unit="ep")
        m2 = read_mapping_file(p)
        assert m2.assignment == m.assignment


class TestExplorer:
    def _graph_with_costs(self):
        g = chain_graph(3)
        # Decreasing token sizes along the chain favour later partition pts.
        for name, fl in [("src", 0.0), ("a0", 5e6), ("a1", 5e6), ("a2", 5e6),
                         ("snk", 0.0)]:
            g.actors[name].cost_flops = fl
        return g

    def _platform(self):
        pg = PlatformGraph("toy")
        pg.add_unit(ProcessingUnit("endpoint", flops=1e9))
        pg.add_unit(ProcessingUnit("server", flops=100e9))
        pg.add_link(Link("endpoint", "server", bandwidth=1e6, latency_s=0.0))
        return pg

    def test_sweep_covers_all_partition_points(self):
        g = self._graph_with_costs()
        res = Explorer(g, self._platform()).evaluate_modeled()
        assert len(res.records) == len(g.actors)
        assert res.records[-1].transfer_s == 0.0  # full endpoint: no tx

    def test_offload_wins_with_fast_link_slow_endpoint(self):
        g = self._graph_with_costs()
        res = Explorer(g, self._platform()).evaluate_modeled()
        # endpoint compute = 15ms total; boundary token = 16B ~ 16us
        best = res.best()
        assert best.pp == 1  # ship everything to the 100x faster server
        assert res.speedup() > 2

    def test_privacy_constraint_excludes_raw_offload(self):
        g = self._graph_with_costs()
        res = Explorer(g, self._platform()).evaluate_modeled()
        assert res.best(privacy=True).pp > 1

    def test_artifact_generation(self, tmp_path):
        g = self._graph_with_costs()
        ex = Explorer(g, self._platform())
        paths = ex.generate_artifacts(str(tmp_path))
        # N actors -> N mapping pairs + 1 profiling script
        assert len(paths) == 2 * len(g.actors) + 1
        m = read_mapping_file(paths[0])
        assert set(m.assignment) == set(g.actors)
