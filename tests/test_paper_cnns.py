"""Paper-fidelity tests: the two experimental CNNs (Sec IV) as VR-PRUNE
graphs, validated against the paper's own published numbers."""
import numpy as np
import pytest

from repro.core import (Simulator, Explorer, analyze, paper_platform,
                        synthesize, Mapping)
from repro.core import calibration as cal
from repro.models.cnn import (dual_input_vehicle_graph, partition_point_after,
                              ssd_mobilenet_graph, vehicle_graph)

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def vg():
    return vehicle_graph()


@pytest.fixture(scope="module")
def ssd():
    return ssd_mobilenet_graph()


class TestVehicleGraphStructure:
    def test_actor_roster_matches_fig2(self, vg):
        assert list(vg.actors) == ["Input", "L1", "L2", "L3", "L4-L5"]

    def test_token_sizes_match_fig2(self, vg):
        """The paper's Fig 2 edge token sizes, byte-exact."""
        assert vg.fifos["L1.out->L2.in"].token_bytes == 294912
        assert vg.fifos["L2.out->L3.in"].token_bytes == 73728
        assert vg.fifos["Input.out->L1.in"].token_bytes == 110592
        assert vg.fifos["L3.out->L4-L5.in"].token_bytes == 400

    def test_graph_is_consistent(self, vg):
        rep = analyze(vg)
        assert rep.ok, rep.errors
        assert set(rep.repetition_vector.values()) == {1}

    def test_inference_executes(self, vg):
        res = Simulator(vg).run(3)
        probs = res.outputs["L4-L5"]
        assert len(probs) == 3
        for p in probs:
            assert p.shape == (4,)
            assert np.isfinite(np.asarray(p)).all()
            np.testing.assert_allclose(np.asarray(p).sum(), 1.0, rtol=1e-5)


class TestVehicleSweepN2:
    """Fig 4: N2-i7 partition sweep."""

    def test_full_endpoint_time(self, vg):
        r = Explorer(vg, paper_platform("N2", "ethernet")).evaluate_modeled()
        assert r.full_endpoint().endpoint_time_s == pytest.approx(
            cal.PAPER_ANCHORS["vehicle_n2_full_endpoint"], rel=0.05)

    def test_pp3_optimal_on_ethernet(self, vg):
        r = Explorer(vg, paper_platform("N2", "ethernet")).evaluate_modeled()
        assert r.best(privacy=True).pp == 3
        assert r.records[2].endpoint_time_s == pytest.approx(
            cal.PAPER_ANCHORS["vehicle_n2_pp3_ethernet"], rel=0.10)

    def test_pp3_optimal_on_wifi(self, vg):
        r = Explorer(vg, paper_platform("N2", "wifi")).evaluate_modeled()
        assert r.best(privacy=True).pp == 3
        assert r.records[2].endpoint_time_s == pytest.approx(
            cal.PAPER_ANCHORS["vehicle_n2_pp3_wifi"], rel=0.05)

    def test_wifi_raw_offload_slower_than_full_endpoint(self, vg):
        """Sec IV.B: 'transmission of raw image data to the edge server
        becomes slower than full endpoint device inference' on WiFi."""
        r = Explorer(vg, paper_platform("N2", "wifi")).evaluate_modeled()
        assert r.records[0].endpoint_time_s > r.full_endpoint().endpoint_time_s

    def test_ethernet_raw_offload_fastest_without_privacy(self, vg):
        r = Explorer(vg, paper_platform("N2", "ethernet")).evaluate_modeled()
        assert r.best(privacy=False).pp == 1
        assert r.records[0].endpoint_time_s == pytest.approx(
            cal.PAPER_ANCHORS["vehicle_n2_pp1_ethernet"], rel=0.15)

    def test_why_pp3_token_size_argument(self, vg):
        """The paper's explanation: L2->L3 token (73728 B) << L1->L2 token
        (294912 B) is why PP3 wins on both links."""
        assert (vg.fifos["L2.out->L3.in"].token_bytes * 4
                == vg.fifos["L1.out->L2.in"].token_bytes)


class TestVehicleSweepN270:
    """Fig 5: N270-i7 partition sweep."""

    def test_full_endpoint_time(self, vg):
        r = Explorer(vg, paper_platform("N270", "ethernet")).evaluate_modeled()
        assert r.full_endpoint().endpoint_time_s == pytest.approx(
            cal.PAPER_ANCHORS["vehicle_n270_full_endpoint"], rel=0.05)

    @pytest.mark.parametrize("conn,anchor,tol", [
        ("ethernet", "vehicle_n270_pp2_ethernet", 0.20),
        ("wifi", "vehicle_n270_pp2_wifi", 0.15),
    ])
    def test_pp2_optimal(self, vg, conn, anchor, tol):
        r = Explorer(vg, paper_platform("N270", conn)).evaluate_modeled()
        assert r.best(privacy=True).pp == 2
        assert r.records[1].endpoint_time_s == pytest.approx(
            cal.PAPER_ANCHORS[anchor], rel=tol)

    def test_collaboration_speedup_significant(self, vg):
        """'collaborative inference improves inference throughput
        significantly' — 443 ms -> 167 ms is 2.65x."""
        r = Explorer(vg, paper_platform("N270", "ethernet")).evaluate_modeled()
        assert r.speedup(privacy=True) > 2.5


class TestSSDMobilenet:
    """Fig 6: SSD-Mobilenet object tracking on N2-i7."""

    def test_graph_structure(self, ssd):
        assert len(ssd.actors) == 35
        assert analyze(ssd).ok
        # branches exist: DWCL11 feeds both DWCL12 and the first head pair
        succ = {a.name for a in ssd.successors(ssd.actors["DWCL11"])}
        assert {"DWCL12", "LOC1", "CONF1"} <= succ

    def test_full_endpoint_time(self, ssd):
        r = Explorer(ssd, paper_platform("N2", "ethernet", workload="ssd")
                     ).evaluate_modeled()
        assert r.full_endpoint().endpoint_time_s == pytest.approx(
            cal.PAPER_ANCHORS["ssd_n2_full_endpoint"], rel=0.05)

    def test_partition_after_dwcl9_matches_paper(self, ssd):
        """Paper: Input..DWCL9 on endpoint -> 406 ms, a 5.8x speedup."""
        pp = partition_point_after(ssd, "DWCL9")
        r = Explorer(ssd, paper_platform("N2", "ethernet", workload="ssd")
                     ).evaluate_modeled()
        rec = r.records[pp - 1]
        assert rec.endpoint_time_s == pytest.approx(
            cal.PAPER_ANCHORS["ssd_n2_best_ethernet"], rel=0.10)
        speedup = r.full_endpoint().endpoint_time_s / rec.endpoint_time_s
        assert speedup == pytest.approx(cal.PAPER_ANCHORS["ssd_speedup"],
                                        rel=0.10)

    def test_optimum_lies_on_739kb_plateau(self, ssd):
        """Our calibrated model finds the optimum on the same 19x19x512
        (739328 B) token plateau the paper reports (DWCL6..DWCL9 cuts are
        within ~20 ms/block of each other — see EXPERIMENTS.md)."""
        for conn in ("ethernet", "wifi"):
            r = Explorer(ssd, paper_platform("N2", conn, workload="ssd")
                         ).evaluate_modeled()
            best = r.best(privacy=True)
            assert best.boundary_bytes == 739328
            assert best.endpoint_actors[-1] in {f"DWCL{i}" for i in range(6, 12)}

    def test_wifi_best_slower_than_ethernet_best(self, ssd):
        """Paper: WiFi minimum 470 ms > Ethernet minimum 406 ms."""
        re = Explorer(ssd, paper_platform("N2", "ethernet", workload="ssd")
                      ).evaluate_modeled()
        rw = Explorer(ssd, paper_platform("N2", "wifi", workload="ssd")
                      ).evaluate_modeled()
        pp = partition_point_after(ssd, "DWCL9")
        # at the paper's own cut, WiFi is slower than Ethernet
        assert (rw.records[pp - 1].endpoint_time_s
                > re.records[pp - 1].endpoint_time_s * 0.99)

    def test_detection_pipeline_executes(self):
        ssd_small = ssd_mobilenet_graph(input_hw=96)  # reduced for CPU speed
        res = Simulator(ssd_small).run(2)
        tracks = res.outputs["Tracker"]
        assert len(tracks) == 2
        assert tracks[0].shape == (10, 5)
        assert np.isfinite(np.asarray(tracks[0])).all()


class TestDualInput:
    """Sec IV.C: two-input vehicle classification across three devices."""

    def test_graph_and_execution(self):
        g = dual_input_vehicle_graph(input_hw=32)
        assert analyze(g).ok
        res = Simulator(g).run(2)
        assert len(res.outputs["L4L5"]) == 2
        np.testing.assert_allclose(np.asarray(res.outputs["L4L5"][0]).sum(),
                                   1.0, rtol=1e-5)

    def test_three_unit_mapping(self):
        g = dual_input_vehicle_graph()
        assignment = {"Input.1": "n2", "L1.1": "n2", "L2.1": "n2",
                      "L3.1": "n2", "Input.2": "n270",
                      "L1.2": "server", "L2.2": "server", "L3.2": "server",
                      "L4L5": "server"}
        prog = synthesize(g, Mapping("dual", assignment))
        assert len(prog.stages) == 3
        # boundary channels: L3.1->L4L5 (n2->server), Input.2->L1.2
        pairs = {(c.src_unit, c.dst_unit) for c in prog.channels}
        assert pairs == {("n2", "server"), ("n270", "server")}


class TestEndToEndLatency:
    """Sec IV.D: single-image e2e latency 31.2 ms = 57/23/20 split."""

    def test_latency_breakdown(self, vg):
        model_pg = paper_platform("N2", "ethernet")
        from repro.core import PlatformModel
        model = PlatformModel(model_pg)
        order = vg.topo_order()
        ep_actors = order[:3]      # Input, L1, L2 on the N2
        sv_actors = order[3:]      # L3, L4-L5 on the i7
        cold = cal.N2_COLD_START_FACTOR
        ep = sum(model.actor_time_s("endpoint", a) for a in ep_actors) * cold
        tx = model.transfer_time_s("endpoint", "server", 73728)
        sv = sum(model.actor_time_s("server", a) for a in sv_actors)
        total = ep + tx + sv
        assert total == pytest.approx(cal.PAPER_ANCHORS["latency_e2e"],
                                      rel=0.10)
        split = (ep / total, tx / total, sv / total)
        for ours, paper in zip(split, cal.PAPER_ANCHORS["latency_split"]):
            assert ours == pytest.approx(paper, abs=0.06)
