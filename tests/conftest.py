"""Shared test configuration.

Registers a fixed hypothesis profile so property tests are reproducible
in CI: ``derandomize=True`` makes every run draw the same example
sequence (a red nightly reproduces locally with no shrinking lottery),
and the per-example deadline is bounded but generous — first examples
pay JAX compiles; tests that interleave many compiles opt out with
``deadline=None`` in their own ``@settings``. Select another profile
with ``HYPOTHESIS_PROFILE=<name>`` (e.g. ``dev`` to re-randomize
locally).
"""
from __future__ import annotations

import os

try:
    from datetime import timedelta

    from hypothesis import settings
except ImportError:                     # fast lane runs without hypothesis
    pass
else:
    settings.register_profile(
        "ci", derandomize=True, deadline=timedelta(seconds=60),
        print_blob=True)
    settings.register_profile("dev", deadline=timedelta(seconds=60))
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
