"""Hierarchical serving (`runtime.escalation`): tiered engines, the
durable escalation queue, degraded modes, and the HTTP integration.

* escalation policies: decide() contracts on fabricated contexts (no
  models involved);
* journal basics: monotone seqs across restarts, bounded capacity,
  idempotent ack (the arbitrary-interleaving half lives in
  ``test_escalation_props.py``);
* token identity: a TieredEngine that never escalates produces greedy
  tokens bit-identical to the plain local engine, and — with the same
  params on both tiers — escalated completions match too (escalation
  moves requests, never content);
* degraded modes: link down + tight deadline => local answer with
  ``finish_reason="local_fallback"``; link down + expired deadline =>
  ``"timeout"`` shed; both reasons are members of ``FINISH_REASONS``;
* fail-back: a link cut strands a deadline-free request in the journal
  (durable wait), revival replays it to the server tier exactly once
  and bumps ``repro_failback_total``;
* HTTP: ``EngineServer`` fronting a TieredEngine serves ``/generate``
  transparently and reports tier identity + escalation state in
  ``/status`` and the escalation counters in ``/metrics``; a plain
  server's ``/escalate`` ingress answers an ``HttpTransport`` send.
"""
from __future__ import annotations

import json

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.escalation import (EscalationContext, EscalationJournal,
                                      FlakyTransport, HttpTransport,
                                      InProcessTransport, JournalFull,
                                      TieredConfig, TieredEngine)
from repro.runtime.policies import (AlwaysEscalate, ConfidenceEscalation,
                                    DeadlineRiskEscalation,
                                    LocalOverloadEscalation, NeverEscalate,
                                    make_escalation)
from repro.runtime.resilience import FailureTrace
from repro.serving import (FINISH_REASONS, Engine, EngineConfig, EngineServer,
                           Request, ServerConfig, parse_prometheus)

KEY = jax.random.PRNGKey(0)


def _tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, KEY)


def _prompts(n, length=6, vocab=64, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=length).astype(np.int32)
            for _ in range(n)]


def _local(cfg, params, **kw):
    kw.setdefault("max_slots", 1)
    kw.setdefault("max_len", 64)
    return Engine(cfg, params, EngineConfig(**kw))


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------


class _Ctx:
    """Fabricated EscalationContext stand-in (duck-typed)."""

    def __init__(self, req=None, snapshot=None, conf=1.0, now_s=0.0):
        self.req = req or Request(id=0, prompt=np.zeros(4, np.int32),
                                  max_new_tokens=8)
        self.snapshot = snapshot or {"queue_depth": 0, "kv": {}}
        self.now_s = now_s
        self._conf = conf

    def confidence(self):
        return self._conf


def test_policy_decisions():
    assert NeverEscalate().decide(_Ctx()) is None
    assert AlwaysEscalate().decide(_Ctx()) == "always"
    conf = ConfidenceEscalation(threshold=0.5)
    assert conf.decide(_Ctx(conf=0.9)) is None
    assert conf.decide(_Ctx(conf=0.1)) == "low_confidence"
    risk = DeadlineRiskEscalation(sec_per_token=0.01, safety=1.0)
    slow = _Ctx(req=Request(id=1, prompt=np.zeros(4, np.int32),
                            max_new_tokens=100, deadline_s=0.5),
                snapshot={"queue_depth": 3, "kv": {}})
    assert risk.decide(slow) == "deadline_risk"          # 4*100*0.01 > 0.5
    assert risk.decide(_Ctx()) is None                   # no deadline
    over = LocalOverloadEscalation(max_queue_depth=2)
    assert over.decide(_Ctx(snapshot={"queue_depth": 5, "kv": {}})) \
        == "local_overload"
    assert over.decide(_Ctx()) is None


def test_make_escalation_specs():
    assert [p.name for p in make_escalation("confidence")] == ["confidence"]
    assert [p.name for p in make_escalation(("confidence", "overload"))] \
        == ["confidence", "overload"]
    inst = ConfidenceEscalation(threshold=0.9)
    assert make_escalation(inst) == [inst]
    with pytest.raises(ValueError):
        make_escalation("no-such-policy")


# ---------------------------------------------------------------------------
# journal
# ---------------------------------------------------------------------------


def test_journal_roundtrip_and_capacity(tmp_path):
    j = EscalationJournal(str(tmp_path), capacity=2)
    r = Request(id=5, prompt=np.arange(4, dtype=np.int32), max_new_tokens=3,
                eos=7, priority=2, deadline_s=1.5)
    s0 = j.append(r, arrival_s=0.25)
    s1 = j.append(Request(id=6, prompt=np.ones(2, np.int32)))
    with pytest.raises(JournalFull):
        j.append(Request(id=7, prompt=np.ones(2, np.int32)))
    assert j.depth == 2 and s1 == s0 + 1

    entries = j.pending()
    assert [e.seq for e in entries] == [s0, s1]
    back = entries[0].req
    assert back.id == 5 and back.eos == 7 and back.priority == 2
    assert back.deadline_s == 1.5 and back.max_new_tokens == 3
    np.testing.assert_array_equal(back.prompt, r.prompt)
    assert entries[0].meta["arrival_s"] == 0.25

    j.ack(s0)
    j.ack(s0)                           # idempotent
    assert [e.seq for e in j.pending()] == [s1]
    # restart: pending survives, seq counter never reuses
    j2 = EscalationJournal(str(tmp_path), capacity=2)
    assert [e.seq for e in j2.pending()] == [s1]
    assert j2.append(Request(id=8, prompt=np.ones(2, np.int32))) == s1 + 1


# ---------------------------------------------------------------------------
# tiered engine: identity + escalation paths
# ---------------------------------------------------------------------------


def test_never_escalate_tokens_bit_identical(setup, tmp_path):
    cfg, params = setup
    prompts = _prompts(3)
    with _local(cfg, params) as plain:
        plain.start()
        want = [plain.submit(Request(id=i, prompt=p, max_new_tokens=6))
                .result(60).tokens for i, p in enumerate(prompts)]

    server = Engine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    tiered = TieredEngine(
        _local(cfg, params), InProcessTransport(server.start()),
        TieredConfig(policies=("never",), journal_dir=str(tmp_path)))
    with tiered, server:
        tiered.start()
        handles = [tiered.submit(Request(id=i, prompt=p, max_new_tokens=6))
                   for i, p in enumerate(prompts)]
        got = [h.result(60).tokens for h in handles]
        assert all(not h.escalated and h.tier == "endpoint" for h in handles)
    assert got == want
    assert tiered.escalation_stats()["escalated"] == 0


def test_always_escalate_matches_and_counts(setup, tmp_path):
    cfg, params = setup
    prompts = _prompts(3, seed=11)
    with _local(cfg, params) as plain:
        plain.start()
        want = [plain.submit(Request(id=i, prompt=p, max_new_tokens=6))
                .result(60).tokens for i, p in enumerate(prompts)]

    server = Engine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    tiered = TieredEngine(
        _local(cfg, params), InProcessTransport(server.start()),
        TieredConfig(policies=("always",), journal_dir=str(tmp_path)))
    with tiered, server:
        tiered.start()
        handles = [tiered.submit(Request(id=i, prompt=p, max_new_tokens=6))
                   for i, p in enumerate(prompts)]
        results = [h.result(60) for h in handles]
        # same params on both tiers: escalation moved the requests, not
        # the content
        assert [c.tokens for c in results] == want
        assert all(h.escalated and h.tier == "server" for h in handles)
        assert all(h.reason == "always" for h in handles)
        stats = tiered.escalation_stats()
        assert stats["escalated"] == 3 and stats["queue_depth"] == 0


def test_stream_surface_on_both_paths(setup, tmp_path):
    cfg, params = setup
    server = Engine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    tiered = TieredEngine(
        _local(cfg, params), InProcessTransport(server.start()),
        TieredConfig(policies=("never",), journal_dir=str(tmp_path / "a")))
    with tiered, server:
        tiered.start()
        h = tiered.submit(Request(id=0, prompt=_prompts(1)[0],
                                  max_new_tokens=5))
        toks = list(h.stream())
        assert h.completion is not None and toks == list(h.completion.tokens)

    server2 = Engine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    tiered2 = TieredEngine(
        _local(cfg, params), InProcessTransport(server2.start()),
        TieredConfig(policies=("always",), journal_dir=str(tmp_path / "b")))
    with tiered2, server2:
        tiered2.start()
        h = tiered2.submit(Request(id=0, prompt=_prompts(1)[0],
                                   max_new_tokens=5))
        toks = list(h.stream())
        assert h.escalated and toks == list(h.completion.tokens)


# ---------------------------------------------------------------------------
# degraded modes: link down
# ---------------------------------------------------------------------------


def _dead_link_transport(server, *, revive_at=None):
    trace = FailureTrace().kill_link("endpoint", "server", at=0.0)
    if revive_at is not None:
        trace.revive_link("endpoint", "server", at=revive_at)
    return FlakyTransport(InProcessTransport(server), trace)


def test_local_fallback_when_link_down_and_deadline_tight(setup, tmp_path):
    cfg, params = setup
    server = Engine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    tiered = TieredEngine(
        _local(cfg, params), _dead_link_transport(server.start()),
        TieredConfig(policies=("always",), journal_dir=str(tmp_path),
                     fallback_slack_s=10.0))     # any deadline => fallback now
    with tiered, server:
        tiered.start()
        h = tiered.submit(Request(id=0, prompt=_prompts(1)[0],
                                  max_new_tokens=5, deadline_s=5.0))
        c = h.result(60)
    assert c.finish_reason == "local_fallback"
    assert c.finish_reason in FINISH_REASONS
    assert len(c.tokens) == 5                    # answered, on-device
    assert h.escalated and h.tier == "endpoint"  # decided up, served down
    stats = tiered.escalation_stats()
    assert stats["local_fallback"] == 1 and stats["escalated"] == 0
    assert stats["queue_depth"] == 0             # fallback acked the entry


def test_timeout_shed_when_link_down_and_deadline_expired(setup, tmp_path):
    cfg, params = setup
    server = Engine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    tiered = TieredEngine(
        _local(cfg, params), _dead_link_transport(server.start()),
        TieredConfig(policies=("always",), journal_dir=str(tmp_path),
                     fallback_slack_s=0.0))      # no fallback window: shed
    with tiered, server:
        tiered.start()
        h = tiered.submit(Request(id=0, prompt=_prompts(1)[0],
                                  max_new_tokens=5, deadline_s=0.05))
        c = h.result(60)
    assert c.finish_reason == "timeout" and c.finish_reason in FINISH_REASONS
    assert c.tokens == []                        # shed, never decoded
    assert tiered.escalation_stats()["sheds"] == 1


def test_link_cut_then_failback_replays_durably(setup, tmp_path):
    cfg, params = setup
    server = Engine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    tiered = TieredEngine(
        _local(cfg, params),
        _dead_link_transport(server.start(), revive_at=1.0),
        TieredConfig(policies=("always",), journal_dir=str(tmp_path)))
    with tiered, server:
        tiered.start()
        # deadline-free: waits durably in the journal through the cut
        hs = [tiered.submit(Request(id=i, prompt=p, max_new_tokens=4))
              for i, p in enumerate(_prompts(2, seed=3))]
        assert tiered.journal.depth == 2         # stranded behind the cut
        results = [h.result(60) for h in hs]     # ...until revival
        assert [c.finish_reason for c in results] == ["length", "length"]
        assert all(h.tier == "server" for h in hs)
        stats = tiered.escalation_stats()
        assert stats["failback"] >= 1 and stats["escalated"] == 2
        assert stats["queue_depth"] == 0 and stats["link_up"]


# ---------------------------------------------------------------------------
# HTTP integration
# ---------------------------------------------------------------------------


def _http(srv, method, path, body=None):
    import http.client
    conn = http.client.HTTPConnection(srv.config.host, srv.port, timeout=120)
    try:
        conn.request(method, path,
                     json.dumps(body) if body is not None else None,
                     {"Content-Type": "application/json"})
        r = conn.getresponse()
        return r.status, r.read()
    finally:
        conn.close()


def test_server_fronting_tiered_engine(setup, tmp_path):
    cfg, params = setup
    remote = Engine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    tiered = TieredEngine(
        _local(cfg, params, observability=True),
        InProcessTransport(remote.start()),
        TieredConfig(policies=("always",), journal_dir=str(tmp_path)))
    with remote, \
            EngineServer(tiered, ServerConfig(port=0, max_inflight=4)) as srv:
        status, raw = _http(srv, "POST", "/generate",
                            {"prompt": [1, 2, 3], "max_new_tokens": 4})
        assert status == 200
        out = json.loads(raw)
        assert len(out["tokens"]) == 4
        assert out["finish_reason"] in FINISH_REASONS

        status, raw = _http(srv, "GET", "/status")
        st = json.loads(raw)
        assert st["tier"] == "endpoint"
        esc = st["escalation"]
        # warmup goes through the policy gate too, so >= the one client
        # request; everything that finished left the journal
        assert esc["escalated"] >= 1 and esc["queue_depth"] == 0

        status, raw = _http(srv, "GET", "/metrics")
        m = parse_prometheus(raw.decode())
        for name in ("repro_escalated_total", "repro_local_fallback_total",
                     "repro_failback_total"):
            assert name in m["counters"], name
        assert "repro_escalation_queue_depth" in m["gauges"]
        assert m["counters"]["repro_escalated_total"] == esc["escalated"]
        assert m["histograms"]["repro_tier_server_ttft_seconds"]["count"] \
            >= 1


def test_escalate_route_and_http_transport(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_slots=2, max_len=64))
    with EngineServer(eng, ServerConfig(port=0, tier="edge-server")) as srv:
        # raw route: metadata echo + tier identity
        status, raw = _http(srv, "POST", "/escalate",
                            {"prompt": [1, 2, 3], "max_new_tokens": 4,
                             "seq": 17, "source": "endpoint"})
        assert status == 200
        out = json.loads(raw)
        assert out["seq"] == 17 and out["tier"] == "edge-server"
        assert len(out["tokens"]) == 4

        # the ingress is counted separately from client traffic
        _, raw = _http(srv, "GET", "/status")
        st = json.loads(raw)
        assert st["tier"] == "edge-server"
        assert st["escalations_received"] == 1

        # HttpTransport: the client half of the same wire
        tr = HttpTransport(srv.url, tier="edge-server")
        assert tr.healthy()
        c = tr.send(Request(id=9, prompt=np.array([1, 2, 3], np.int32),
                            max_new_tokens=4), seq=18)
        assert len(c.tokens) == 4 and c.finish_reason in FINISH_REASONS
        _, raw = _http(srv, "GET", "/status")
        assert json.loads(raw)["escalations_received"] == 2
