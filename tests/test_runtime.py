"""Runtime integration: the optimizer actually learns (copy task), the
serving engine generates coherently with caches, checkpoints round-trip,
and grad accumulation equals the monolithic step."""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime import checkpoint, data, optim
from repro.runtime.serving import Request, ServeEngine
from repro.runtime.trainstep import make_train_step

KEY = jax.random.PRNGKey(0)


def _tiny_cfg():
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


def test_training_learns_copy_task():
    cfg = _tiny_cfg()
    params = T.init_params(cfg, KEY)
    opt = optim.init(params)
    step = jax.jit(make_train_step(
        cfg, optim.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=200,
                               weight_decay=0.0)))
    gen = data.copy_task_batches(16, 16, cfg.vocab_size, seed=1)
    losses = []
    for i, batch in zip(range(150), gen):
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])
    assert losses[-1] < 1.0, losses[-1]


def test_grad_accumulation_matches_monolithic():
    cfg = _tiny_cfg()
    params = T.init_params(cfg, KEY)
    opt = optim.init(params)
    oc = optim.AdamWConfig(lr=1e-3, warmup_steps=1)
    batch = next(data.lm_batches(8, 16, cfg.vocab_size))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    p1, _, m1 = jax.jit(make_train_step(cfg, oc))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, oc, microbatches=4))(
        params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    # fp32 reduction order differs between the two paths; Adam's
    # rsqrt(v)+eps amplifies that slightly on near-zero-grad params
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_serving_engine_greedy_matches_forward_argmax():
    cfg = _tiny_cfg()
    params = T.init_params(cfg, KEY)
    eng = ServeEngine(cfg, params, max_len=64)
    prompts = [np.arange(8, dtype=np.int32) % cfg.vocab_size,
               (np.arange(8, dtype=np.int32) * 3) % cfg.vocab_size]
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    outs = eng.generate(reqs)
    assert [o.id for o in outs] == [0, 1]
    # oracle: step-by-step full forward argmax
    for o, prompt in zip(outs, prompts):
        toks = list(prompt)
        for expected in o.tokens:
            logits, _ = T.forward(
                params, cfg,
                {"tokens": jnp.asarray(np.array(toks)[None])}, train=False)
            assert int(jnp.argmax(logits[0, -1])) == expected
            toks.append(expected)


def test_checkpoint_roundtrip():
    cfg = _tiny_cfg()
    params = T.init_params(cfg, KEY)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        checkpoint.save(path, params, meta={"step": 3, "cfg": cfg.name})
        template = jax.eval_shape(lambda: params)
        restored = checkpoint.restore(path, template)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert checkpoint.load_meta(path)["step"] == 3


def test_lm_batches_deterministic_and_in_range():
    g1 = data.lm_batches(4, 32, 100, seed=5)
    g2 = data.lm_batches(4, 32, 100, seed=5)
    b1, b2 = next(g1), next(g2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 100
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
