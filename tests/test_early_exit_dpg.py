"""The variable-rate conditional-offload example (VR-PRUNE CA/DA/DPA
machinery) runs end-to-end: analyzer-clean, every frame classified, and
the offload decision actually varies at run time."""
import pathlib
import runpy

import pytest


def test_early_exit_offload_example(capsys):
    path = pathlib.Path(__file__).parent.parent / "examples" / \
        "early_exit_offload.py"
    runpy.run_path(str(path), run_name="__main__")
    out = capsys.readouterr().out
    assert "analyzer: ok=True" in out
    assert "rates symmetric" in out
    # the decision must be non-degenerate: some offloaded, some not
    import re
    m = re.search(r"offloaded \(conf<[\d.]+\): (\d+) \((\d+)%\)", out)
    assert m, out
    frac = int(m.group(2))
    assert 0 < frac < 100
