"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode on
CPU) and the XLA production implementation are asserted allclose against
the pure-jnp oracle in ref.py."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (decode_attention,
                                                paged_decode_attention)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                paged_decode_attention_ref)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_seq_ref
from repro.models.layers import decode_attention_xla, flash_attention_xla
from repro.models.rglru import rglru_scan_ref as rglru_assoc_ref

pytestmark = [pytest.mark.kernels, pytest.mark.slow]

KEY = jax.random.PRNGKey(7)


FA_CASES = [
    # (sq, sk, h, hk, d, causal, window, dtype)
    (64, 64, 4, 2, 32, True, 0, jnp.float32),
    (128, 128, 4, 1, 64, True, 24, jnp.float32),
    (32, 32, 2, 2, 16, False, 0, jnp.float32),
    (64, 64, 8, 4, 128, True, 0, jnp.bfloat16),
    (96, 96, 4, 4, 64, True, 32, jnp.float32),
    (256, 256, 2, 1, 128, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("sq,sk,h,hk,d,causal,window,dtype", FA_CASES)
def test_flash_attention_pallas_vs_ref(sq, sk, h, hk, d, causal, window,
                                       dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (2, sk, hk, d), dtype)
    v = jax.random.normal(ks[2], (2, sk, hk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("sq,sk,h,hk,d,causal,window,dtype", FA_CASES)
def test_flash_attention_xla_vs_ref(sq, sk, h, hk, d, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (2, sk, hk, d), dtype)
    v = jax.random.normal(ks[2], (2, sk, hk, d), dtype)
    out = flash_attention_xla(q, k, v, causal=causal, window=window, chunk=32)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_xla_grads_vs_ref():
    """The custom flash VJP must match the oracle's autodiff grads."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 48, 4, 32))
    k = jax.random.normal(ks[1], (2, 48, 2, 32))
    v = jax.random.normal(ks[2], (2, 48, 2, 32))
    for causal, window in [(True, 0), (True, 12), (False, 0)]:
        f = lambda *a: jnp.sum(jnp.sin(flash_attention_xla(
            *a, causal=causal, window=window, chunk=16)))
        g = lambda *a: jnp.sum(jnp.sin(attention_ref(
            *a, causal=causal, window=window)))
        gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        gg = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gf, gg):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-3)


DEC_CASES = [
    (2, 4, 2, 32, 64, jnp.float32),
    (3, 8, 1, 64, 128, jnp.float32),
    (1, 4, 4, 128, 256, jnp.bfloat16),
    (4, 16, 2, 64, 96, jnp.float32),
]


@pytest.mark.parametrize("b,h,hk,d,s,dtype", DEC_CASES)
def test_decode_attention_pallas_vs_ref(b, h, hk, d, s, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hk, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hk, d), dtype)
    lens = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention(q, kc, vc, lens, bk=32)
    ref = decode_attention_ref(q, kc, vc, lens)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,hk,d,s,dtype", DEC_CASES)
def test_decode_attention_xla_vs_ref(b, h, hk, d, s, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, hk, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, hk, d), dtype)
    lens = jax.random.randint(ks[3], (b,), 1, s + 1)
    out = decode_attention_xla(q, kc, vc, lens)
    ref = decode_attention_ref(q, kc, vc, lens)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


PAGED_CASES = [
    # (b, h, hk, d, num_blocks, block_size, nb_pages, dtype)
    (2, 4, 2, 32, 9, 8, 4, jnp.float32),
    (3, 8, 1, 64, 5, 16, 2, jnp.float32),
    (1, 4, 4, 128, 17, 8, 8, jnp.bfloat16),
    (4, 8, 2, 64, 13, 16, 3, jnp.float32),
]


@pytest.mark.parametrize("b,h,hk,d,n,bs,nb,dtype", PAGED_CASES)
def test_paged_decode_attention_pallas_vs_ref(b, h, hk, d, n, bs, nb, dtype):
    """Block-table gather path: the kernel must stream exactly the pages
    named by the table (including repeated/null physical blocks) and mask
    rows past each sequence's length."""
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kp = jax.random.normal(ks[1], (n, bs, hk, d), dtype)
    vp = jax.random.normal(ks[2], (n, bs, hk, d), dtype)
    tables = jax.random.randint(ks[3], (b, nb), 0, n)
    lens = jax.random.randint(ks[4], (b,), 1, nb * bs + 1)
    out = paged_decode_attention(q, kp, vp, tables, lens)
    ref = paged_decode_attention_ref(q, kp, vp, tables, lens)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,hk,d,n,bs,nb,dtype", PAGED_CASES)
def test_paged_gather_xla_vs_ref(b, h, hk, d, n, bs, nb, dtype):
    """The scheduler's XLA fallback (gather pages to a contiguous view,
    then dense decode attention) equals the paged oracle."""
    from repro.models.layers import paged_gather
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kp = jax.random.normal(ks[1], (n, bs, hk, d), dtype)
    vp = jax.random.normal(ks[2], (n, bs, hk, d), dtype)
    tables = jax.random.randint(ks[3], (b, nb), 0, n)
    lens = jax.random.randint(ks[4], (b,), 1, nb * bs + 1)
    out = decode_attention_xla(q, paged_gather(kp, tables),
                               paged_gather(vp, tables), lens)
    ref = paged_decode_attention_ref(q, kp, vp, tables, lens)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


SCAN_CASES = [
    (2, 64, 128), (3, 100, 96), (1, 256, 512), (2, 17, 40),
]


@pytest.mark.parametrize("B,S,D", SCAN_CASES)
def test_rglru_scan_pallas_vs_seq_ref(B, S, D):
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, D), minval=0.5, maxval=0.999)
    b = jax.random.normal(ks[1], (B, S, D)) * 0.1
    h0 = jax.random.normal(ks[2], (B, D))
    y = rglru_scan(a, b, h0, bs=32, bd=64)
    yr = rglru_scan_seq_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,S,D", SCAN_CASES)
def test_rglru_assoc_scan_vs_seq_ref(B, S, D):
    """The production associative-scan lowering equals the sequential scan."""
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, D), minval=0.5, maxval=0.999)
    b = jax.random.normal(ks[1], (B, S, D)) * 0.1
    h0 = jax.random.normal(ks[2], (B, D))
    y = rglru_assoc_ref(a, b, h0)
    yr = rglru_scan_seq_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


def test_mlstm_chunkwise_matches_decode_recurrence():
    """Chunkwise-parallel mLSTM == token-by-token recurrent form."""
    from repro.models.ssm import mlstm_chunkwise
    b, s, nh, dh = 2, 48, 2, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, s, nh, dh))
    k = jax.random.normal(ks[1], (b, s, nh, dh))
    v = jax.random.normal(ks[2], (b, s, nh, dh))
    log_i = jax.random.normal(ks[3], (b, s, nh))
    log_f = -jax.nn.softplus(-jax.random.normal(ks[4], (b, s, nh)) - 1.0)
    out, (C, n, m) = mlstm_chunkwise(q, k, v, log_i, log_f, None, chunk=16)

    # sequential oracle
    import numpy as onp
    qn, kn, vn = (onp.asarray(x, onp.float64) for x in (q, k, v))
    li, lf = onp.asarray(log_i, onp.float64), onp.asarray(log_f, onp.float64)
    scale = 1.0 / onp.sqrt(dh)
    C_ = onp.zeros((b, nh, dh, dh))
    n_ = onp.zeros((b, nh, dh))
    m_ = onp.full((b, nh), -1e30)
    outs = onp.zeros((b, s, nh, dh))
    for t in range(s):
        m_new = onp.maximum(lf[:, t] + m_, li[:, t])
        decay = onp.exp(lf[:, t] + m_ - m_new)
        inw = onp.exp(li[:, t] - m_new)
        C_ = decay[..., None, None] * C_ + inw[..., None, None] \
            * onp.einsum("bhd,bhe->bhde", kn[:, t], vn[:, t])
        n_ = decay[..., None] * n_ + inw[..., None] * kn[:, t]
        qt = qn[:, t] * scale
        num = onp.einsum("bhd,bhde->bhe", qt, C_)
        den = onp.maximum(onp.abs(onp.einsum("bhd,bhd->bh", qt, n_)),
                          onp.exp(-m_new))
        outs[:, t] = num / den[..., None]
        m_ = m_new
    np.testing.assert_allclose(np.asarray(out), outs, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(C), C_, rtol=2e-4, atol=2e-4)
