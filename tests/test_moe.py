"""MoE routing semantics: capacity dispatch vs the dense oracle,
load-balance loss behaviour, and dropping under tight capacity."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as M
from repro.models.config import ModelConfig, MoEConfig

KEY = jax.random.PRNGKey(11)


def _cfg(n_experts=4, top_k=2, cf=8.0, shared=0):
    return ModelConfig(
        name="moe-test", arch_type="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=128,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_ff_expert=32,
                      n_shared_experts=shared, capacity_factor=cf),
        dtype="float32", param_dtype="float32")


def test_moe_matches_dense_oracle_with_slack_capacity():
    cfg = _cfg(cf=8.0)
    p = M.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = M.moe_apply(p, x, cfg)
    y_ref, aux_ref = M.moe_apply_dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-3,
                               atol=1e-2)


def test_moe_shared_experts_added():
    cfg = _cfg(shared=2)
    p = M.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, _ = M.moe_apply(p, x, cfg)
    y_ref, _ = M.moe_apply_dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_moe_tight_capacity_drops_but_keeps_residual():
    """With capacity ~0, every token drops: output == residual (+shared)."""
    cfg = _cfg(cf=1e-6)
    p = M.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model))
    y, _ = M.moe_apply(p, x, cfg)
    # capacity is floored at 4 slots, so at most 4*E tokens routed; with 64
    # tokens * top2 = 128 assignments >> 16 slots, most pass through.
    delta = np.abs(np.asarray(y - x)).mean()
    cfg_big = _cfg(cf=8.0)
    y_big, _ = M.moe_apply(p, x, cfg_big)
    delta_big = np.abs(np.asarray(y_big - x)).mean()
    assert delta < delta_big        # dropping reduces applied expert mass


def test_load_balance_loss_minimal_when_uniform():
    """Uniform routing probs -> aux ~ 1 (its minimum); concentrated routing
    -> aux >> 1."""
    cfg = _cfg(n_experts=4, top_k=1)
    g, t, e = 1, 256, 4
    uniform = jnp.zeros((g, t, e))
    disp, comb, aux_u = M.route(uniform, cfg, capacity=256)
    skew = jnp.concatenate([jnp.full((g, t, 1), 10.0),
                            jnp.zeros((g, t, e - 1))], -1)
    _, _, aux_s = M.route(skew, cfg, capacity=256)
    assert float(aux_s) > float(aux_u)
    assert float(aux_u) == np.testing.assert_allclose(
        float(aux_u), 1.0, rtol=0.1) or True


def test_capacity_priority_is_first_choice_first():
    """1st-choice assignments win capacity slots over 2nd choices."""
    cfg = _cfg(n_experts=2, top_k=2, cf=1e-6)   # capacity floors at 4
    g, t, e = 1, 16, 2
    logits = jnp.stack([jnp.full((g, t), 5.0), jnp.zeros((g, t))], -1)
    disp, comb, _ = M.route(logits, cfg, capacity=4)
    d = np.asarray(disp)
    # expert 0 gets the first 4 tokens as 1st choice
    assert d[0, :4, 0].any(axis=-1).all()
    assert not d[0, 4:, 0].any()
