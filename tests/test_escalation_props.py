"""Property-based escalation-journal tests (hypothesis).

The durable-queue contract, stated as a property: for *any*
interleaving of appends, link up/down flips, lost acknowledgements,
replay steps, and crash-restarts (journal + replayer rebuilt from disk,
all in-memory state lost), once the link is up long enough to drain —

* every appended request reaches the server tier at least once
  (durability: nothing journaled is ever lost),
* every appended request is *surfaced* (completion handed to the
  caller) exactly once (the delivered-set de-dup absorbs resends whose
  first ack was lost),
* first deliveries happen in append order (head-of-line replay: a dead
  link stops the walk, it never reorders it),
* the journal directory ends empty — acks really delete, nothing
  leaks across crashes — and sequence numbers stay strictly monotone
  across restarts (seq reuse would break the de-dup).

No engines or models here: the replayer is deliberately synchronous and
thread-free so this suite can drive the exact protocol code the
``TieredEngine`` pump runs, one operation at a time.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.runtime.escalation import (EscalationJournal, JournalFull,
                                      JournalReplayer, LinkDown)
from repro.runtime.scheduler import Completion, Request

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see "
    "requirements-dev.txt); the fast lane skips them")
from hypothesis import given, settings, strategies as st  # noqa: E402

CAPACITY = 8


class FakeServerTransport:
    """Server tier as a ledger. ``up`` models the link; ``drop_next_ack``
    models the nastiest failure: the server computes the completion but
    the link dies before the reply lands (at-least-once territory)."""

    tier = "server"

    def __init__(self):
        self.up = True
        self.drop_next_ack = False
        self.computed = []              # seqs the server actually ran

    def healthy(self):
        return self.up

    def send(self, req: Request, *, seq=None) -> Completion:
        if not self.up:
            raise LinkDown("link down")
        self.computed.append(seq)
        if self.drop_next_ack:
            self.drop_next_ack = False
            raise LinkDown("ack lost")
        return Completion(req.id, [int(t) for t in req.prompt], 0.0, 0.0,
                          finish_reason="eos")


OPS = st.lists(
    st.sampled_from(["append", "append", "step", "step", "link_down",
                     "link_up", "drop_ack", "crash"]),
    min_size=1, max_size=40)


@settings(max_examples=60, deadline=None)
@given(ops=OPS, window=st.sampled_from([1, 3]))
def test_property_exactly_once_in_order_no_leak(ops, window,
                                                tmp_path_factory):
    # window=1 is the thread-free serial protocol; window=3 pipelines
    # sends — the invariants must hold identically for both
    root = str(tmp_path_factory.mktemp("journal"))
    transport = FakeServerTransport()
    surfaced = []                       # (seq, completion) in surfacing order

    def on_complete(entry, c):
        surfaced.append((entry.seq, c))

    def boot():
        j = EscalationJournal(root, capacity=CAPACITY)
        return j, JournalReplayer(j, transport, on_complete=on_complete,
                                  window=window)

    journal, replayer = boot()
    appended = []                       # (seq, prompt) accepted by the journal
    n = 0
    for op in ops:
        if op == "append":
            prompt = np.arange(n, n + 3, dtype=np.int32)
            try:
                seq = journal.append(
                    Request(id=n, prompt=prompt, max_new_tokens=4))
            except JournalFull:
                assert journal.depth == CAPACITY
            else:
                appended.append((seq, prompt))
            n += 1
        elif op == "step":
            replayer.step()
        elif op == "link_down":
            transport.up = False
        elif op == "link_up":
            transport.up = True
        elif op == "drop_ack":
            transport.drop_next_ack = True
        elif op == "crash":
            # process dies between operations: journal + replayer state
            # (including the delivered set) is lost; disk survives
            journal, replayer = boot()

    # revive the link and drain
    transport.up = True
    transport.drop_next_ack = False
    for _ in range(len(appended) + 2):
        if journal.depth == 0:
            break
        replayer.step()
    assert journal.depth == 0, "journal did not drain on a healthy link"

    want = [seq for seq, _ in appended]
    got = [seq for seq, _ in surfaced]
    # exactly once, in append order (strictly increasing == in order +
    # no duplicates), nothing lost
    assert got == sorted(set(got)), f"reordered or duplicated: {got}"
    assert got == want, f"surfaced {got} != appended {want}"
    # durability: the server computed every journaled request >= once
    # (resends after a lost ack make it > once — that is the point)
    assert set(transport.computed) == set(want)
    assert len(transport.computed) >= len(want)
    # payload integrity through serialize -> replay -> completion
    prompts = dict(appended)
    for seq, c in surfaced:
        assert c.tokens == [int(t) for t in prompts[seq]], seq
    # seqs strictly monotone across crash-restarts (no reuse)
    assert all(a < b for a, b in zip(want, want[1:]))
    # no on-disk leak: acks deleted every record, only the seq-counter
    # state file remains
    leftovers = [f for f in os.listdir(root) if f != "journal.state.json"]
    assert leftovers == [], leftovers


@settings(max_examples=25, deadline=None)
@given(n_appends=st.integers(1, 6), crash_at=st.integers(0, 6))
def test_property_crash_preserves_pending_and_seq_monotone(
        n_appends, crash_at, tmp_path_factory):
    """A restart rebuilds exactly the unacked set, in order, and never
    reissues a sequence number — even when the journal drained to empty
    before the crash (the state file carries the counter)."""
    root = str(tmp_path_factory.mktemp("journal"))
    journal = EscalationJournal(root, capacity=64)
    seqs = [journal.append(Request(id=i, prompt=np.full(2, i, np.int32)))
            for i in range(n_appends)]
    acked = seqs[:min(crash_at, n_appends)]
    for s in acked:
        journal.ack(s)

    reborn = EscalationJournal(root, capacity=64)
    assert [e.seq for e in reborn.pending()] == seqs[len(acked):]
    fresh = reborn.append(Request(id=99, prompt=np.zeros(2, np.int32)))
    assert fresh > max(seqs), (fresh, seqs)
