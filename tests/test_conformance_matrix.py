"""Greedy-identity conformance matrix for the serving stack.

One differential suite pins the stack's core contract in one place
(consolidating the ad-hoc identity checks that used to be scattered
through test_scheduler.py and test_engine_lifecycle.py): under greedy
sampling, EVERY serving configuration —

    {slotted, slotted+chunked-prefill, paged, paged+chunked-prefill,
     paged+prefix-cache, paged+chunked+prefix, paged+prefix+victim,
     disaggregated (dedicated prefill unit + 2 decode stages),
     pipelined-decode (stage-partitioned decode step)}
  x {fifo, priority, deadline-EDF, batch}
  x {evict-latest, lowest-priority}
  x 2 model configs (scan-only depth, and scan+remainder depth)

— must emit tokens (and finish reasons) bit-identical to the
static-bucket oracle. Policies move waiting time, never content; cache
layouts move memory, never content; prefix sharing moves *prefill work*,
never content. The workload is adversarial on purpose: overlapping
prompt prefixes (so prefix-cache cells actually share blocks), an eos
stop, single-token budgets, scrambled priorities and deadlines, and a
pool tight enough to force growth preemption in paged cells (so the
preemption policy axis is actually exercised).

The full matrix is heavy (every cell builds and drains an engine), so
only a representative diagonal runs in the fast CI lane; the rest is
``slow`` and runs nightly.
"""
from __future__ import annotations

import itertools

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.scheduler import Request

KEY = jax.random.PRNGKey(0)

CONFIGS = {
    # scan-only depth: 2 layers = 2 periods of ("attn",)
    "scan": ModelConfig(
        name="cm-scan", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False),
    # scan + remainder depth: 3 layers over a period of 2 leaves one
    # unrolled remainder layer — the cache pytree's "rem" half
    "rem": ModelConfig(
        name="cm-rem", arch_type="dense", n_layers=3, d_model=48,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=96, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False,
        layer_pattern=("attn", "attn"), tie_embeddings=True),
}

LAYOUTS = {
    "slotted": dict(kv_layout="slotted"),
    "slotted-chunked": dict(kv_layout="slotted", prefill_chunk=4),
    "paged": dict(kv_layout="paged", block_size=8, num_blocks=18),
    "paged-chunked": dict(kv_layout="paged", block_size=8, num_blocks=18,
                          prefill_chunk=4),
    "paged-prefix": dict(kv_layout="paged", block_size=8, num_blocks=18,
                         prefix_cache=True),
    "paged-chunked-prefix": dict(kv_layout="paged", block_size=8,
                                 num_blocks=18, prefill_chunk=4,
                                 prefix_cache=True),
    # victim cache on top of prefix sharing: completed chains park in a
    # reclaimable pool instead of freeing. Retention moves prefill work
    # only — tokens must stay oracle-identical, and at drain the books
    # balance against the parked population instead of zero.
    "paged-prefix-victim": dict(kv_layout="paged", block_size=8,
                                num_blocks=18, prefix_cache=True,
                                victim_cache=True),
    # multi-unit execution core: prefill/decode disaggregation (one
    # dedicated prefill unit, two decode stages) over the full paged +
    # chunked feature load, and pipelined stage-partitioned decode on
    # the slotted layout. Placement/units move modeled time only —
    # tokens must stay oracle-identical.
    "disagg": dict(kv_layout="paged", block_size=8, num_blocks=18,
                   prefill_chunk=4, units=3, prefill_units=1,
                   decode_stages=2, placement="least-loaded"),
    "pipelined-decode": dict(kv_layout="slotted", units=2,
                             prefill_units=0, decode_stages=2),
}

ADMISSIONS = ("fifo", "priority", "edf", "batch")
PREEMPTIONS = ("evict-latest", "lowest-priority")

# the fast-lane diagonal: every layout, every admission and both
# preemption policies appear at least once on each model config
FAST = {
    ("scan", "slotted", "batch", "evict-latest"),
    ("scan", "slotted", "fifo", "evict-latest"),
    ("scan", "slotted-chunked", "fifo", "evict-latest"),
    ("rem", "slotted-chunked", "edf", "evict-latest"),
    ("scan", "paged", "priority", "lowest-priority"),
    ("scan", "paged-chunked", "edf", "evict-latest"),
    ("scan", "paged-prefix", "fifo", "evict-latest"),
    ("scan", "paged-prefix", "priority", "lowest-priority"),
    ("scan", "paged-chunked-prefix", "edf", "lowest-priority"),
    ("rem", "slotted", "batch", "evict-latest"),
    ("rem", "slotted", "priority", "evict-latest"),
    ("rem", "paged", "fifo", "evict-latest"),
    ("rem", "paged-chunked", "priority", "lowest-priority"),
    ("rem", "paged-prefix", "edf", "lowest-priority"),
    ("rem", "paged-chunked-prefix", "fifo", "evict-latest"),
    ("scan", "paged-prefix-victim", "fifo", "evict-latest"),
    ("rem", "paged-prefix-victim", "priority", "lowest-priority"),
    ("scan", "disagg", "fifo", "evict-latest"),
    ("rem", "disagg", "priority", "lowest-priority"),
    ("scan", "pipelined-decode", "fifo", "evict-latest"),
    ("rem", "pipelined-decode", "edf", "evict-latest"),
}


def _cells():
    for cfg, lay, adm, pre in itertools.product(CONFIGS, LAYOUTS,
                                                ADMISSIONS, PREEMPTIONS):
        if adm == "batch" and lay != "slotted":
            continue        # rejected combination (engine raises; see below)
        if LAYOUTS[lay].get("kv_layout") == "slotted" \
                and pre != "evict-latest":
            continue        # no pool -> preemption never engages; one
            #                 representative per slotted cell is enough
        marks = () if (cfg, lay, adm, pre) in FAST else (pytest.mark.slow,)
        yield pytest.param(cfg, lay, adm, pre,
                           id=f"{cfg}-{lay}-{adm}-{pre}", marks=marks)


@pytest.fixture(scope="module")
def zoo():
    """Params and the static-bucket oracle tokens, once per config."""
    out = {}
    for name, cfg in CONFIGS.items():
        params = T.init_params(cfg, KEY)
        oracle = Engine(cfg, params, EngineConfig(
            max_len=48, admission="batch")).generate(_workload(cfg))
        out[name] = (cfg, params, oracle)
    return out


def _workload(cfg: ModelConfig):
    """Mixed prompts with a shared 12-token preamble on most requests
    (prefix cells must share), one eos stop, one single-token budget,
    scrambled priorities/deadlines. Worst case 4 blocks of 8 rows, so a
    tight 17-block pool forces growth preemption with 3+ slots busy."""
    rng = np.random.RandomState(7)
    shared = rng.randint(0, cfg.vocab_size, 12).astype(np.int32)
    specs = [(5, 6), (12, 4), (8, 9), (16, 5), (7, 1), (9, 8), (12, 7),
             (16, 2), (8, 6), (14, 5)]
    reqs = []
    for i, (plen, mnew) in enumerate(specs):
        if i % 3 == 0:      # unrelated prompt: must never falsely match
            prompt = rng.randint(0, cfg.vocab_size, plen).astype(np.int32)
        else:               # shared preamble + private tail
            tail = rng.randint(0, cfg.vocab_size,
                               max(plen - 12, 1)).astype(np.int32)
            prompt = np.concatenate([shared, tail])
        reqs.append(Request(i, prompt, max_new_tokens=mnew,
                            priority=(i * 7) % 3,
                            deadline_s=None if i % 4 == 0
                            else 0.01 * ((i * 5) % 4)))
    # an exact duplicate of a shared-preamble prompt: the whole-prompt
    # (partial tail block) match, boundary copy-on-write at insert
    reqs.append(Request(len(specs), reqs[1].prompt.copy(),
                        max_new_tokens=3))
    # an eos that fires mid-stream for request 2 (probed from the oracle
    # by the fixture consumer; here just reserve the slot)
    return reqs


@pytest.mark.parametrize("cfg_name,layout,admission,preemption", _cells())
def test_matrix_cell_matches_static_oracle(zoo, cfg_name, layout, admission,
                                           preemption):
    cfg, params, oracle = zoo[cfg_name]
    reqs = _workload(cfg)
    kw = dict(LAYOUTS[layout])
    if admission == "batch":
        eng = Engine(cfg, params, EngineConfig(
            max_len=48, admission="batch", **kw))
    else:
        eng = Engine(cfg, params, EngineConfig(
            max_len=48, max_slots=3, admission=admission,
            preemption=preemption, debug=True, **kw))
    outs = eng.generate(reqs)
    assert [c.id for c in outs] == [c.id for c in oracle]
    for ref, got in zip(oracle, outs):
        assert got.tokens == ref.tokens, \
            f"request {ref.id} diverged in cell {cfg_name}-{layout}-" \
            f"{admission}-{preemption}"
        assert got.finish_reason == ref.finish_reason
    sched = eng.scheduler
    if sched is None:
        return
    st = sched.stats()
    assert st["admissions"] >= len(reqs)
    if kw.get("kv_layout") == "paged":
        # the pool comes home whole: no leaked or double-freed blocks.
        # With the victim cache on, "home" is the parked population —
        # every in-use block is accounted for by the victim pool.
        parked = len(sched.layout.victim) if kw.get("victim_cache") else 0
        assert sched.alloc.in_use == parked
        assert sched.alloc.available == sched.alloc.capacity - parked
        assert not sched.block_tables.any()
        assert not sched.cache_len.any() and not sched.tokens.any()
        if kw.get("victim_cache"):
            assert parked > 0, "no chain survived the drain"
            sched.layout.check(set(), 3)
    if kw.get("prefix_cache"):
        assert st["prefix_hits"] > 0, "shared-prefix workload never shared"
        assert st["prefill_tokens_saved"] > 0


def test_matrix_cell_with_eos(zoo):
    """Eos stops agree across the matrix's most feature-loaded cell: the
    token streams truncate at the same point with the same reason."""
    cfg, params, _ = zoo["scan"]
    reqs = _workload(cfg)
    probe = Engine(cfg, params, EngineConfig(
        max_len=48, admission="batch")).generate(reqs)
    eos = probe[2].tokens[3]            # occurs mid-stream for request 2
    ref = Engine(cfg, params, EngineConfig(
        max_len=48, admission="batch")).generate(_with_eos(_workload(cfg),
                                                           eos))
    eng = Engine(cfg, params, EngineConfig(
        max_len=48, max_slots=3, kv_layout="paged", block_size=8,
        num_blocks=18, prefill_chunk=4, prefix_cache=True,
        admission="priority", preemption="lowest-priority", debug=True))
    outs = eng.generate(_with_eos(_workload(cfg), eos))
    assert [c.tokens for c in outs] == [c.tokens for c in ref]
    assert [c.finish_reason for c in outs] == [c.finish_reason for c in ref]
    assert "eos" in {c.finish_reason for c in ref}


def _with_eos(reqs, eos):
    for r in reqs:
        r.eos = eos
    return reqs


def test_invalid_cells_are_rejected(zoo):
    """The matrix's structural holes are loud, not silent: batch
    admission refuses paged layouts / chunked prefill, and prefix
    sharing refuses the slotted layout."""
    cfg, params, _ = zoo["scan"]
    with pytest.raises(ValueError, match="batch admission"):
        Engine(cfg, params, EngineConfig(admission="batch",
                                         kv_layout="paged"))
    with pytest.raises(ValueError, match="batch admission"):
        Engine(cfg, params, EngineConfig(admission="batch",
                                         prefill_chunk=4))
    with pytest.raises(ValueError, match="prefix_cache"):
        Engine(cfg, params, EngineConfig(kv_layout="slotted",
                                         prefix_cache=True))
    with pytest.raises(ValueError, match="prefix_cache"):
        Engine(cfg, params, EngineConfig(kv_layout="paged",
                                         victim_cache=True))
