"""Resilience subsystem tests: failure model, simulator failure injection,
heartbeat failover, checkpoint replay, and scheduler requeue.

The central contract under test is the fault-tolerant Edge-PRUNE property
(arXiv 2206.08152): the application graph never changes, only the mapping
does — so after any recoverable failure, every served frame/request must
be *bit-identical* to the failure-free run, and frames acked before the
failure must never be recomputed differently.
"""
from __future__ import annotations

import pytest

from repro.core import (Actor, ActorType, Graph, Link, Mapping, Port,
                        PortDir, PlatformGraph, PlatformModel,
                        ProcessingUnit, SimResult, Simulator, synthesize)
from repro.runtime.resilience import (CheckpointBuffer, FailoverController,
                                      FailureInjector, FailureTrace,
                                      HeartbeatConfig, HeartbeatMonitor)

HB = HeartbeatConfig(interval_s=1e-4, timeout_s=2e-4)


# ---------------------------------------------------------------------------
# helpers: a pure-python int chain (bit-exactness is trivially observable)
# ---------------------------------------------------------------------------

def chain_graph(n_mid: int = 2, muls=None) -> Graph:
    """Source -> n_mid affine stages -> Sink, int tokens, 1e6 flops each
    (so modeled firings take 1 ms on a 1 GFLOP/s unit)."""
    muls = muls or [10 + i for i in range(n_mid)]
    g = Graph(f"chain{n_mid}")
    src = Actor("Src", ActorType.SPA, [],
                [Port("out", PortDir.OUT, token_shape=(), token_dtype="int32")],
                fire_fn=lambda ins, st, atr: ({"out": [ins["__feed__"][0]]}, st),
                cost_flops=1e6)
    g.add_actor(src)
    prev = src
    for i in range(n_mid):
        def make_fire(m):
            return lambda ins, st, atr: ({"out": [ins["in"][0] * m + 1]}, st)
        a = Actor(f"M{i}", ActorType.SPA,
                  [Port("in", PortDir.IN, token_shape=(), token_dtype="int32")],
                  [Port("out", PortDir.OUT, token_shape=(), token_dtype="int32")],
                  fire_fn=make_fire(muls[i]), cost_flops=1e6)
        g.add_actor(a)
        g.connect(prev.port("out"), a.port("in"), capacity=64)
        prev = a
    snk = Actor("Snk", ActorType.SPA,
                [Port("in", PortDir.IN, token_shape=(), token_dtype="int32")], [],
                fire_fn=lambda ins, st, atr: ({"result": [ins["in"][0]]}, st),
                cost_flops=1e6)
    g.add_actor(snk)
    g.connect(prev.port("out"), snk.port("in"), capacity=64)
    return g


def two_unit_platform() -> PlatformModel:
    pg = PlatformGraph("p2")
    pg.add_unit(ProcessingUnit("endpoint", flops=1e9, mem_bandwidth=1e9))
    pg.add_unit(ProcessingUnit("server", flops=1e9, mem_bandwidth=1e9))
    pg.add_link(Link("endpoint", "server", bandwidth=1e9, latency_s=1e-5))
    return PlatformModel(pg)


def partition(g: Graph, pp: int) -> Mapping:
    """First ``pp`` actors (topo order) on the endpoint, rest on server —
    pipeline-ordered, both units used for 1 <= pp < N."""
    order = [a.name for a in g.topo_order()]
    return Mapping(f"pp{pp}", {n: ("endpoint" if i < pp else "server")
                               for i, n in enumerate(order)})


def all_on(g: Graph, unit: str) -> Mapping:
    return Mapping(f"all-{unit}", {n: unit for n in g.actors})


# ---------------------------------------------------------------------------
# failure model
# ---------------------------------------------------------------------------

def test_failure_trace_intervals():
    t = (FailureTrace().kill_unit("u", at=1.0).revive_unit("u", at=2.0)
         .kill_unit("u", at=3.0))
    assert not t.unit_dead_at("u", 0.5)
    assert t.unit_dead_at("u", 1.0) and t.unit_dead_at("u", 1.999)
    assert not t.unit_dead_at("u", 2.0)
    assert t.unit_dead_at("u", 100.0)          # second kill never revives
    assert t.unit_next_alive("u", 1.5) == 2.0
    assert t.unit_next_alive("u", 3.5) is None
    assert t.unit_killed_between("u", 0.5, 1.5)
    assert not t.unit_killed_between("u", 1.2, 1.8)
    assert t.unit_killed_between("u", 2.5, 3.0)


def test_failure_trace_links_symmetric():
    t = FailureTrace().kill_link("a", "b", at=1.0)
    assert t.link_dead_at("b", "a", 2.0)
    assert t.link_next_alive("a", "b", 2.0) is None
    assert t.dead_links(2.0) == [frozenset(("a", "b"))]


def test_first_kill_affecting_scopes_to_components():
    t = (FailureTrace().kill_unit("x", at=1.0)
         .kill_unit("server", at=2.0).kill_link("a", "b", at=3.0))
    e = t.first_kill_affecting(["server"], [("a", "b")], after=0.0)
    assert e.t_s == 2.0
    e = t.first_kill_affecting(["nope"], [("a", "b")], after=0.0)
    assert e.t_s == 3.0
    assert t.first_kill_affecting(["nope"], [], after=0.0) is None
    assert t.first_kill_affecting(["server"], [], after=2.0) is None


def test_failure_injector_delivers_in_order():
    t = FailureTrace().kill_unit("u", at=1.0).revive_unit("u", at=2.0)
    inj = FailureInjector(t)
    assert inj.advance(0.5) == []
    ev = inj.advance(1.5)
    assert len(ev) == 1 and ev[0].action == "kill"
    assert len(inj.advance(10.0)) == 1 and inj.exhausted


def test_heartbeat_detection_and_validation():
    m = HeartbeatMonitor(HeartbeatConfig(interval_s=0.05, timeout_s=0.15))
    # last beat before a kill at 0.12 was at 0.10 -> declared at 0.25
    assert m.detect_time(0.12) == pytest.approx(0.25)
    assert m.detect_time(0.0) == pytest.approx(0.15)
    with pytest.raises(ValueError, match="timeout"):
        HeartbeatConfig(interval_s=0.1, timeout_s=0.05)


def test_checkpoint_buffer_bounded_fifo():
    b = CheckpointBuffer(2)
    b.push(0, "f0")
    b.push(1, "f1")
    with pytest.raises(OverflowError, match="full"):
        b.push(2, "f2")
    b.ack(0)
    b.push(2, "f2")
    assert [fid for fid, _ in b.unacked()] == [1, 2]


# ---------------------------------------------------------------------------
# simulator failure injection
# ---------------------------------------------------------------------------

def _sim(g, mapping, pm, frames, failures=None):
    feed = {"Src": list(range(1, frames + 1))}
    return Simulator(g, mapping=mapping, platform=pm).run(
        frames, source_inputs=feed, failures=failures)


def test_simulator_kill_revive_replays_bit_exact():
    pm = two_unit_platform()
    nom = _sim(chain_graph(), partition(chain_graph(), 2), pm, 8)
    tr = FailureTrace().kill_unit("server", at=0.0025).revive_unit(
        "server", at=0.006)
    res = _sim(chain_graph(), partition(chain_graph(), 2), pm, 8, failures=tr)
    assert res.outputs["Snk"] == nom.outputs["Snk"]
    assert res.frames_replayed and not res.frames_lost
    # downtime + replay must push completion out
    assert res.modeled_makespan_s > nom.modeled_makespan_s
    assert res.failure_log


def test_simulator_kill_forever_loses_frames():
    pm = two_unit_platform()
    tr = FailureTrace().kill_unit("server", at=0.0025)
    res = _sim(chain_graph(), partition(chain_graph(), 2), pm, 8, failures=tr)
    nom = _sim(chain_graph(), partition(chain_graph(), 2), pm, 8)
    assert res.frames_lost, "dead-forever unit must lose frames"
    served = len(res.outputs["Snk"])
    assert served == 8 - len(res.frames_lost)
    # what *was* served is still the bit-exact prefix
    assert res.outputs["Snk"] == nom.outputs["Snk"][:served]


def test_simulator_link_failure_delays_or_replays():
    pm = two_unit_platform()
    nom = _sim(chain_graph(), partition(chain_graph(), 2), pm, 8)
    tr = FailureTrace().kill_link("endpoint", "server", at=0.0015) \
        .revive_link("endpoint", "server", at=0.005)
    res = _sim(chain_graph(), partition(chain_graph(), 2), pm, 8, failures=tr)
    assert res.outputs["Snk"] == nom.outputs["Snk"]
    assert res.modeled_makespan_s > nom.modeled_makespan_s


def test_simulator_failures_none_is_legacy_path():
    pm = two_unit_platform()
    a = _sim(chain_graph(), partition(chain_graph(), 2), pm, 5)
    b = _sim(chain_graph(), partition(chain_graph(), 2), pm, 5,
             failures=FailureTrace())
    assert a.outputs["Snk"] == b.outputs["Snk"]
    assert b.frames_replayed == [] and b.frames_lost == []


def _port(name, d):
    return Port(name, d, token_shape=(), token_dtype="int32")


def diamond_graph() -> Graph:
    """Src fans one frame out to B and C; J joins the branches — the
    whole-frame-consistency stress case: losing one branch's token must
    purge the surviving branch too, or J pairs different frames."""
    g = Graph("diamond")
    src = Actor("Src", ActorType.SPA, [],
                [_port("o1", PortDir.OUT), _port("o2", PortDir.OUT)],
                fire_fn=lambda ins, st, atr: (
                    {"o1": [ins["__feed__"][0]], "o2": [ins["__feed__"][0]]},
                    st),
                cost_flops=1e6)
    b = Actor("B", ActorType.SPA, [_port("in", PortDir.IN)],
              [_port("out", PortDir.OUT)],
              fire_fn=lambda ins, st, atr: ({"out": [ins["in"][0] * 10]}, st),
              cost_flops=1e6)
    c = Actor("C", ActorType.SPA, [_port("in", PortDir.IN)],
              [_port("out", PortDir.OUT)],
              fire_fn=lambda ins, st, atr: ({"out": [ins["in"][0] * 3]}, st),
              cost_flops=1e6)
    j = Actor("J", ActorType.SPA,
              [_port("i1", PortDir.IN), _port("i2", PortDir.IN)], [],
              fire_fn=lambda ins, st, atr: (
                  {"result": [(ins["i1"][0], ins["i2"][0])]}, st),
              cost_flops=1e6)
    for a in (src, b, c, j):
        g.add_actor(a)
    g.connect(src.port("o1"), b.port("in"), capacity=64)
    g.connect(src.port("o2"), c.port("in"), capacity=64)
    g.connect(b.port("out"), j.port("i1"), capacity=64)
    g.connect(c.port("out"), j.port("i2"), capacity=64)
    return g


def test_simulator_fanout_join_stays_frame_aligned():
    """One branch crosses the dying unit, the other stays healthy: replay
    must purge the healthy branch's surviving tokens so the join never
    pairs branch outputs from different frames."""
    pm = two_unit_platform()
    m = Mapping("d", {"Src": "endpoint", "B": "endpoint", "C": "server",
                      "J": "endpoint"})
    feed = {"Src": [2 * i for i in range(5)]}
    nom = Simulator(diamond_graph(), mapping=m, platform=pm).run(
        5, source_inputs=feed)
    tr = FailureTrace().kill_unit("server", at=5e-4).revive_unit(
        "server", at=2.5e-3)
    res = Simulator(diamond_graph(), mapping=m, platform=pm).run(
        5, source_inputs=feed, failures=tr)
    assert res.outputs["J"] == nom.outputs["J"]
    assert res.frames_replayed and not res.frames_lost


def test_simulator_multiple_losses_one_outage_single_replay_round():
    """Both branches land on the dead unit: two token losses of the same
    frame are one replay round, not two burned attempts — the frame must
    still recover after the revival."""
    pm = two_unit_platform()
    m = Mapping("d2", {"Src": "endpoint", "B": "server", "C": "server",
                       "J": "endpoint"})
    feed = {"Src": [2 * i for i in range(5)]}
    nom = Simulator(diamond_graph(), mapping=m, platform=pm).run(
        5, source_inputs=feed)
    tr = FailureTrace().kill_unit("server", at=5e-4).revive_unit(
        "server", at=2.5e-3)
    res = Simulator(diamond_graph(), mapping=m, platform=pm).run(
        5, source_inputs=feed, failures=tr)
    assert res.outputs["J"] == nom.outputs["J"]
    assert not res.frames_lost


def test_simulator_dead_source_unit_accounts_all_frames():
    """Killing the unit hosting the source must report every unserved
    frame in frames_lost — never-fired frames included."""
    pm = two_unit_platform()
    tr = FailureTrace().kill_unit("endpoint", at=2.2e-3)
    res = _sim(chain_graph(), partition(chain_graph(), 2), pm, 5,
               failures=tr)
    assert len(res.outputs["Snk"]) + len(res.frames_lost) == 5
    assert res.frames_lost == sorted(res.frames_lost)


def test_simulator_rejects_unsupported_graph_classes_under_failures():
    """Whole-frame replay cannot roll back actor state, reproduce
    variable rates, or preserve loop-carried delay tokens — combining
    failures= with those graph features must raise, not corrupt."""
    pm = two_unit_platform()
    tr = FailureTrace().kill_unit("server", at=1.0)

    g = chain_graph()
    g.actors["M0"].init_fn = lambda: 0
    with pytest.raises(ValueError, match="stateless"):
        Simulator(g, mapping=partition(g, 2), platform=pm).run(
            2, source_inputs={"Src": [1, 2]}, failures=tr)

    g2 = chain_graph()
    with pytest.raises(ValueError, match="static-rate"):
        Simulator(g2, mapping=partition(g2, 2), platform=pm,
                  atr_fn=lambda a, k: {}).run(
            2, source_inputs={"Src": [1, 2]}, failures=tr)

    g3 = Graph("loop")
    a = Actor("A", ActorType.SPA,
              [_port("in", PortDir.IN)], [_port("out", PortDir.OUT)],
              fire_fn=lambda ins, st, atr: ({"out": [ins["in"][0] + 1]}, st))
    g3.add_actor(a)
    g3.connect(a.port("out"), a.port("in"), delay_tokens=1)
    with pytest.raises(ValueError, match="feedback"):
        Simulator(g3, platform=pm,
                  mapping=Mapping("l", {"A": "server"})).run(
            2, failures=tr)
    # ...and the same graphs still simulate fine without failure injection
    out = Simulator(g, mapping=partition(g, 2), platform=pm).run(
        2, source_inputs={"Src": [1, 2]})
    assert len(out.outputs["Snk"]) == 2


def test_pipeline_speedup_guards_empty_run():
    assert SimResult(outputs={}).pipeline_speedup == 1.0
    # makespan set but zero modeled charges (no platform): still 1.0,
    # not a ZeroDivisionError / 0-by-0
    assert SimResult(outputs={}, modeled_makespan_s=1.0).pipeline_speedup == 1.0
    res = Simulator(chain_graph()).run(3, source_inputs={"Src": [1, 2, 3]})
    assert res.pipeline_speedup == 1.0


# ---------------------------------------------------------------------------
# failover controller: property + edge cases
# ---------------------------------------------------------------------------

def _controller(g, primary, fallbacks, pm, *, window=None):
    return FailoverController(g, primary, fallbacks, platform=pm,
                              heartbeat=HB,
                              checkpoint_frames=window or 64)


def test_failover_mid_stream_server_loss():
    g = chain_graph()
    pm = two_unit_platform()
    primary = partition(g, 2)
    frames = [{"Src": i} for i in range(10)]
    nominal, nrep = _controller(g, primary, [all_on(g, "endpoint")],
                                pm).serve(frames)
    assert nrep.num_failovers == 0
    ctl = _controller(g, primary, [all_on(g, "endpoint")], pm, window=4)
    outs, rep = ctl.serve(
        frames, failures=FailureTrace().kill_unit("server", at=0.004))
    assert rep.num_failovers == 1 and not rep.exhausted
    assert rep.frames_replayed and not rep.frames_unserved
    assert ctl.mapping.units_used() == ["endpoint"]
    assert [o["Snk"] for o in outs] == [o["Snk"] for o in nominal]
    ev = rep.events[0]
    assert ev.t_detect_s >= ev.t_fail_s
    assert ev.recovery_latency_s > 0


def test_failover_during_prefill():
    """Kill before the first frame ever acks: everything replays on the
    fallback and the full stream is still served bit-exactly."""
    g = chain_graph()
    pm = two_unit_platform()
    frames = [{"Src": i} for i in range(6)]
    nominal, _ = _controller(g, partition(g, 2),
                             [all_on(g, "endpoint")], pm).serve(frames)
    ctl = _controller(g, partition(g, 2), [all_on(g, "endpoint")], pm)
    outs, rep = ctl.serve(
        frames, failures=FailureTrace().kill_unit("server", at=0.0))
    assert [o["Snk"] for o in outs] == [o["Snk"] for o in nominal]
    assert rep.num_failovers == 1 and not rep.frames_unserved


def test_failover_of_only_fallback_exhausts():
    g = chain_graph()
    pm = two_unit_platform()
    frames = [{"Src": i} for i in range(10)]
    ctl = _controller(g, partition(g, 2), [all_on(g, "endpoint")], pm,
                      window=4)
    tr = (FailureTrace().kill_unit("server", at=0.004)
          .kill_unit("endpoint", at=0.009))
    outs, rep = ctl.serve(frames, failures=tr)
    assert rep.exhausted and rep.frames_unserved
    # served prefix is committed, the rest is explicitly None
    nominal, _ = _controller(g, partition(g, 2),
                             [all_on(g, "endpoint")],
                             pm).serve(frames)
    for i, o in enumerate(outs):
        if i in rep.frames_unserved:
            assert o is None
        else:
            assert o["Snk"] == nominal[i]["Snk"]


def test_failover_link_only_failure():
    """A dead link with both units alive still breaks every boundary-
    crossing mapping: the controller must fall over to a single-unit
    mapping and keep the stream bit-exact."""
    g = chain_graph()
    pm = two_unit_platform()
    frames = [{"Src": i} for i in range(8)]
    nominal, _ = _controller(g, partition(g, 2),
                             [all_on(g, "endpoint")], pm).serve(frames)
    ctl = _controller(g, partition(g, 2), [all_on(g, "endpoint")], pm,
                      window=3)
    outs, rep = ctl.serve(
        frames,
        failures=FailureTrace().kill_link("endpoint", "server", at=0.003))
    assert rep.num_failovers == 1
    assert len(ctl.mapping.units_used()) == 1
    assert [o["Snk"] for o in outs] == [o["Snk"] for o in nominal]


def test_mapping_excluding_remaps_dead_units():
    g = chain_graph()
    m = partition(g, 2)
    fb = m.excluding(["server"], "endpoint")
    assert fb.units_used() == ["endpoint"]
    assert set(fb.assignment) == set(m.assignment)
    with pytest.raises(ValueError, match="dead set"):
        m.excluding(["server"], "server")


# The hypothesis property test (any mapping x any single-unit failure
# after frame k => frames 0..k bit-exact) lives in
# tests/test_resilience_props.py so this module still runs when
# hypothesis is absent (module-level importorskip skips a whole file).
