"""Property-based paged-KV accounting tests (hypothesis).

The paged scheduler's contract, stated as properties:

* the ``BlockAllocator`` never leaks or double-frees across ANY
  interleaving of allocations and frees — the books (free + held ==
  capacity, null block untouchable) balance after every operation, and
  freeing a block twice raises instead of silently corrupting the pool;
* a ``ContinuousScheduler`` drain over ANY workload/failure interleaving
  (admissions, evictions, chunked prefills, ``SlotFailure`` injections,
  growth preemptions under an oversubscribed pool) returns every block
  exactly once: per-step invariants hold (``debug=True``), every request
  still gets its full token budget, and the pool is whole afterwards.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.scheduler import (BlockAllocator, ContinuousScheduler,
                                     Request, SchedulerConfig, SlotFailure)

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see "
    "requirements-dev.txt); the fast lane skips them")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=200, deadline=None)
@given(data=st.data())
def test_property_allocator_books_balance(data):
    """Random alloc/free interleavings: accounting stays exact, the null
    block never circulates, and a double-free raises."""
    num_blocks = data.draw(st.integers(2, 24), label="num_blocks")
    alloc = BlockAllocator(num_blocks, block_size=4)
    held: list = []
    for _ in range(data.draw(st.integers(0, 40), label="n_ops")):
        if held and data.draw(st.booleans(), label="free?"):
            k = data.draw(st.integers(1, len(held)), label="n_free")
            batch, held = held[:k], held[k:]
            alloc.free(batch)
        else:
            n = data.draw(st.integers(0, num_blocks), label="n_alloc")
            avail = alloc.available
            got = alloc.alloc(n)
            if n > avail:
                assert got is None, "over-committed the pool"
            else:
                assert got is not None and len(got) == n and 0 not in got
                held.extend(got)
        alloc.check()
        assert alloc.in_use == len(held)
        assert alloc.hwm >= alloc.in_use
    if held:
        alloc.free(held)
        with pytest.raises(ValueError, match="double free|not held"):
            alloc.free(held[:1])


CFG = ModelConfig(
    name="tiny-props", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    param_dtype="float32", attn_chunk=16, remat=False)
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
# few distinct prompt lengths => the one-shot prefill compiles stay cached
PROMPT_LENS = (4, 6, 8)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_property_no_block_leaks_under_any_interleaving(data):
    """Random workloads + random SlotFailure injections over a (possibly
    oversubscribed) paged pool, with step-boundary invariants on: every
    request completes its budget and every block comes home."""
    rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 16),
                                          label="seed"))
    n_req = data.draw(st.integers(2, 6), label="n_req")
    max_slots = data.draw(st.integers(1, 3), label="max_slots")
    chunk = data.draw(st.sampled_from([0, 4]), label="prefill_chunk")
    # capacity >= one request's worst case (8 + 6 - 1 rows -> 4 blocks)
    num_blocks = data.draw(st.integers(5, 13), label="num_blocks")
    reqs = [Request(i, rng.randint(0, CFG.vocab_size,
                                   PROMPT_LENS[i % len(PROMPT_LENS)]
                                   ).astype(np.int32),
                    max_new_tokens=int(rng.randint(1, 7)))
            for i in range(n_req)]
    n_fail = data.draw(st.integers(0, 3), label="n_fail")
    failures = [SlotFailure(step=data.draw(st.integers(0, 25),
                                           label=f"fail_step{i}"),
                            slots=data.draw(st.sampled_from(
                                [None, (0,), (0, 1)]), label=f"fail_slots{i}"))
                for i in range(n_fail)]
    sched = ContinuousScheduler(
        CFG, PARAMS, SchedulerConfig(max_slots=max_slots, max_len=16,
                                     paged=True, block_size=4,
                                     num_blocks=num_blocks,
                                     prefill_chunk=chunk, debug=True),
        failures=failures)
    for r in reqs:
        sched.submit(r)
    outs = sched.run()
    assert [o.id for o in outs] == list(range(n_req)), "request dropped"
    for o, r in zip(outs, reqs):
        assert len(o.tokens) == r.max_new_tokens
    assert sched.alloc.in_use == 0, "leaked blocks"
    assert sched.alloc.available == sched.alloc.capacity
    assert not sched.block_tables.any()
    assert not sched.cache_len.any() and not sched.tokens.any()
