"""Property-based paged-KV accounting tests (hypothesis).

The paged scheduler's contract, stated as properties:

* the ``BlockAllocator`` never leaks or double-frees across ANY
  interleaving of allocations and frees — the books (free + held ==
  capacity, null block untouchable) balance after every operation, and
  freeing a block twice raises instead of silently corrupting the pool;
* with reference counting in play (``share``/``release``), counts track
  an exact model across ANY alloc/share/release interleaving: never
  negative, a block frees exactly when its last reference drops, and a
  shared block survives any strict subset of its holders releasing;
* a ``ContinuousScheduler`` drain over ANY workload/failure interleaving
  (admissions, evictions, chunked prefills, ``SlotFailure`` injections,
  growth preemptions under an oversubscribed pool) returns every block
  exactly once: per-step invariants hold (``debug=True``), every request
  still gets its full token budget, and the pool is whole afterwards;
* the same holds with ``prefix_cache`` sharing on and prompts drawn with
  overlapping prefixes, with cancellation and preemption in the mix: a
  block referenced by a live request is never handed out again (the
  per-step debug invariant pins refcount == table references exactly),
  and the pool is fully free at drain with an empty prefix index.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.scheduler import (BlockAllocator, ContinuousScheduler,
                                     Request, SchedulerConfig, SlotFailure)

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see "
    "requirements-dev.txt); the fast lane skips them")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=200, deadline=None)
@given(data=st.data())
def test_property_allocator_books_balance(data):
    """Random alloc/free interleavings: accounting stays exact, the null
    block never circulates, and a double-free raises."""
    num_blocks = data.draw(st.integers(2, 24), label="num_blocks")
    alloc = BlockAllocator(num_blocks, block_size=4)
    held: list = []
    for _ in range(data.draw(st.integers(0, 40), label="n_ops")):
        if held and data.draw(st.booleans(), label="free?"):
            k = data.draw(st.integers(1, len(held)), label="n_free")
            batch, held = held[:k], held[k:]
            alloc.free(batch)
        else:
            n = data.draw(st.integers(0, num_blocks), label="n_alloc")
            avail = alloc.available
            got = alloc.alloc(n)
            if n > avail:
                assert got is None, "over-committed the pool"
            else:
                assert got is not None and len(got) == n and 0 not in got
                held.extend(got)
        alloc.check()
        assert alloc.in_use == len(held)
        assert alloc.hwm >= alloc.in_use
    if held:
        alloc.free(held)
        with pytest.raises(ValueError, match="double free|not held"):
            alloc.free(held[:1])


@settings(max_examples=200, deadline=None)
@given(data=st.data())
def test_property_refcounts_track_exact_model(data):
    """Random alloc/share/release interleavings against a reference
    model: counts never go negative (releasing an unheld block raises),
    a block returns to the pool exactly when its model count hits zero,
    and accounting (in_use / available / check) stays exact throughout."""
    num_blocks = data.draw(st.integers(2, 24), label="num_blocks")
    alloc = BlockAllocator(num_blocks, block_size=4)
    model: dict = {}                    # block -> expected refcount
    for _ in range(data.draw(st.integers(0, 60), label="n_ops")):
        op = data.draw(st.sampled_from(
            ["alloc", "share", "release"]), label="op")
        if op == "alloc":
            n = data.draw(st.integers(0, num_blocks), label="n_alloc")
            got = alloc.alloc(n)
            if n > num_blocks - 1 - len(model):
                assert got is None, "over-committed the pool"
            else:
                assert got is not None and len(got) == n and 0 not in got
                for b in got:
                    assert b not in model, "handed out a held block"
                    model[b] = 1
        elif op == "share" and model:
            picks = data.draw(st.lists(st.sampled_from(sorted(model)),
                                       max_size=6), label="share")
            alloc.share(picks)
            for b in picks:
                model[b] += 1
        elif op == "release" and model:
            picks = data.draw(st.lists(st.sampled_from(sorted(model)),
                                       max_size=6, unique=True),
                              label="release")
            freed = alloc.release(picks)
            expect_freed = []
            for b in picks:
                model[b] -= 1
                if model[b] == 0:
                    del model[b]
                    expect_freed.append(b)
            assert freed == expect_freed
        alloc.check()
        assert alloc.in_use == len(model)
        for b, c in model.items():
            assert alloc.refcount(b) == c
        assert alloc.refcount(0) == 0
    # drain the model completely; a further release must raise
    while model:
        b = next(iter(model))
        alloc.release([b] * model.pop(b))
    assert alloc.available == alloc.capacity
    with pytest.raises(ValueError, match="double free|not held"):
        alloc.release([1])
    with pytest.raises(ValueError, match="not held"):
        alloc.share([1])


CFG = ModelConfig(
    name="tiny-props", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    param_dtype="float32", attn_chunk=16, remat=False)
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
# few distinct prompt lengths => the one-shot prefill compiles stay cached
PROMPT_LENS = (4, 6, 8)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_property_no_block_leaks_under_any_interleaving(data):
    """Random workloads + random SlotFailure injections over a (possibly
    oversubscribed) paged pool, with step-boundary invariants on: every
    request completes its budget and every block comes home."""
    rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 16),
                                          label="seed"))
    n_req = data.draw(st.integers(2, 6), label="n_req")
    max_slots = data.draw(st.integers(1, 3), label="max_slots")
    chunk = data.draw(st.sampled_from([0, 4]), label="prefill_chunk")
    # capacity >= one request's worst case (8 + 6 - 1 rows -> 4 blocks)
    num_blocks = data.draw(st.integers(5, 13), label="num_blocks")
    reqs = [Request(i, rng.randint(0, CFG.vocab_size,
                                   PROMPT_LENS[i % len(PROMPT_LENS)]
                                   ).astype(np.int32),
                    max_new_tokens=int(rng.randint(1, 7)))
            for i in range(n_req)]
    n_fail = data.draw(st.integers(0, 3), label="n_fail")
    failures = [SlotFailure(step=data.draw(st.integers(0, 25),
                                           label=f"fail_step{i}"),
                            slots=data.draw(st.sampled_from(
                                [None, (0,), (0, 1)]), label=f"fail_slots{i}"))
                for i in range(n_fail)]
    sched = ContinuousScheduler(
        CFG, PARAMS, SchedulerConfig(max_slots=max_slots, max_len=16,
                                     paged=True, block_size=4,
                                     num_blocks=num_blocks,
                                     prefill_chunk=chunk, debug=True),
        failures=failures)
    for r in reqs:
        sched.submit(r)
    outs = sched.run()
    assert [o.id for o in outs] == list(range(n_req)), "request dropped"
    for o, r in zip(outs, reqs):
        assert len(o.tokens) == r.max_new_tokens
    assert sched.alloc.in_use == 0, "leaked blocks"
    assert sched.alloc.available == sched.alloc.capacity
    assert not sched.block_tables.any()
    assert not sched.cache_len.any() and not sched.tokens.any()


# shared 8-token preamble pool: prompts drawn below overlap pairwise on
# whole blocks (block_size=4), so prefix matches actually occur
_PREFIX_RNG = np.random.RandomState(99)
PREFIXES = [_PREFIX_RNG.randint(0, CFG.vocab_size, 8).astype(np.int32)
            for _ in range(2)]


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_property_prefix_sharing_interleavings(data):
    """Arbitrary admit/evict/cancel/fail/preempt interleavings with
    overlapping prompt prefixes under ``prefix_cache=True``: per-step
    debug invariants pin refcounts to table references exactly (so a
    block referenced by a live request can never be reused — it is not
    in the free list while referenced), refcounts never go negative
    (allocator check), completions are exactly one per request with
    frozen streams after cancel, and at drain the pool is fully free
    with an empty prefix index."""
    rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 16),
                                          label="seed"))
    n_req = data.draw(st.integers(2, 7), label="n_req")
    max_slots = data.draw(st.integers(1, 3), label="max_slots")
    chunk = data.draw(st.sampled_from([0, 4]), label="prefill_chunk")
    # worst case: 8 + 4 prompt + 6 new tokens - 1 -> 17 rows -> 5 blocks
    num_blocks = data.draw(st.integers(6, 14), label="num_blocks")
    n_fail = data.draw(st.integers(0, 2), label="n_fail")
    failures = [SlotFailure(step=data.draw(st.integers(0, 20),
                                           label=f"fail_step{i}"),
                            slots=data.draw(st.sampled_from(
                                [None, (0,), (0, 1)]),
                                label=f"fail_slots{i}"))
                for i in range(n_fail)]
    eng = Engine(CFG, PARAMS, EngineConfig(
        max_len=20, max_slots=max_slots, kv_layout="paged", block_size=4,
        num_blocks=num_blocks, prefill_chunk=chunk, prefix_cache=True,
        admission=data.draw(st.sampled_from(["fifo", "priority", "edf"]),
                            label="admission"),
        preemption=data.draw(st.sampled_from(
            ["evict-latest", "lowest-priority"]), label="preemption"),
        debug=True), failures=failures)
    handles, frozen = [], {}
    for i in range(n_req):
        head = PREFIXES[data.draw(st.integers(0, len(PREFIXES) - 1),
                                  label=f"head{i}")]
        tail_len = data.draw(st.integers(0, 4), label=f"tail{i}")
        prompt = np.concatenate(
            [head, rng.randint(0, CFG.vocab_size, tail_len)
             .astype(np.int32)]) if tail_len else head.copy()
        h = eng.submit(Request(
            i, prompt, max_new_tokens=int(rng.randint(1, 7)),
            priority=int(rng.randint(0, 3)),
            deadline_s=None if rng.rand() < 0.5
            else float(rng.rand() * 0.2)))
        cancel_at = data.draw(st.sampled_from([None, 0, 2]),
                              label=f"cancel_at{i}")
        if cancel_at == 0:
            h.cancel()
            frozen[i] = []
        elif cancel_at is not None:
            def make_cb(h=h, at=cancel_at, i=i):
                def cb(tok):
                    if len(h.tokens) >= at and i not in frozen:
                        h.cancel()
                        frozen[i] = list(h.tokens)
                return cb
            h.on_token(make_cb())
        handles.append(h)
    outs = eng.run()
    assert sorted(c.id for c in outs) == list(range(n_req)), \
        "request lost or duplicated"
    for h, c in zip(handles, sorted(outs, key=lambda c: c.id)):
        if c.finish_reason == "cancelled":
            assert h.tokens == frozen[c.id], \
                "token emitted after cancel() returned"
        elif c.finish_reason == "length":
            assert len(c.tokens) == h.request.max_new_tokens
    sched = eng.scheduler
    assert sched.done
    assert sched.alloc.in_use == 0, "leaked blocks"
    assert sched.alloc.available == sched.alloc.capacity
    assert not sched.block_tables.any()
    assert sorted(sched.free) == list(range(max_slots)), "slot leak"
    lay = sched.layout
    assert not lay._prefix_full and not lay._prefix_partial
    assert not lay._block_keys, "prefix index outlived its blocks"
    assert not lay._slot_blocks and not lay._table_pending
