"""Prefix-cache service correctness battery.

The victim cache turns the prefix index into a cross-request service:
released refcount-1 prefix chains park in a reclaimable pool instead of
freeing, so a later request — in a later drain epoch, after the pool
fully idled — can still resume from them. These tests pin the contract:

* a refcount-0 chain survives its owner's completion and is re-hit by a
  cold admission (``victim_hits`` counts exactly these; it is
  structurally zero with the victim cache off);
* under allocation pressure the weighted-LRU policy evicts cold chains
  before hot ones (plain LRU would evict by recency alone) — and an
  idle parked chain is always sacrificed before a live request is
  preempted;
* ``save_prefix_cache``/``restore_prefix_cache`` round-trip the pool
  bit-identically: a fresh engine restored from the checkpoint produces
  the same tokens AND registers victim hits on the replay;
* per-tenant byte quotas evict only the breaching tenant's chains, and
  a tenant never resolves another tenant's identical prompt to shared
  blocks (namespace isolation);
* regression: the prefix index follows block lifetime across drain
  epochs — entries for parked blocks stay alive, entries for freed
  blocks die with them.
"""
from __future__ import annotations

import os

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.policies import (LruEviction, WeightedLruEviction,
                                    make_victim_eviction)
from repro.runtime.scheduler import Request, VictimCache

CFG = ModelConfig(
    name="tiny-pc", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    param_dtype="float32", attn_chunk=16, remat=False)
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))

BLOCK = 8
ROW_BYTES = T.kv_row_bytes(CFG)


def _engine(victim=True, num_blocks=24, tenants=None, **kw):
    return Engine(CFG, PARAMS, EngineConfig(
        max_slots=4, max_len=64, kv_layout="paged", block_size=BLOCK,
        num_blocks=num_blocks, prefix_cache=True, victim_cache=victim,
        prefix_cache_tenants=tenants, greedy=True, seed=0, debug=True,
        **kw))


def _prompt(seed, n=20):
    return (np.arange(n, dtype=np.int32) * (seed + 3) + seed) % CFG.vocab_size


def _run(eng, prompts, tenants=None, max_new=8):
    tenants = tenants or [""] * len(prompts)
    outs = eng.generate([Request(i, p, max_new_tokens=max_new, tenant=t)
                         for i, (p, t) in enumerate(zip(prompts, tenants))])
    return [c.tokens for c in sorted(outs, key=lambda c: c.id)]


# -- cross-drain survival ---------------------------------------------------

def test_victim_chain_survives_completion_and_rehits():
    """Wave 1 drains fully (refcount-0 everywhere); wave 2 re-sends the
    same prompt and must resume from the parked chain: victim_hits > 0,
    prefill work saved, and tokens identical to an uncached engine."""
    p = _prompt(1)
    eng = _engine(victim=True)
    w1 = _run(eng, [p])
    lay = eng.scheduler.layout
    assert eng.scheduler.alloc.in_use == len(lay.victim) > 0, \
        "completed chain did not park in the victim pool"
    assert lay._prefix_full, "prefix index died with the drain epoch"
    w2 = _run(eng, [p])
    snap = eng.snapshot()["prefix_cache"]
    assert snap["victim_hits"] > 0, snap
    assert snap["prefill_tokens_saved"] > 0 and snap["bytes_saved"] > 0
    assert np.array_equal(w1[0], w2[0]), "cache hit changed the tokens"
    # oracle: same prompt on a victim-less engine gives the same stream
    cold = _run(_engine(victim=False), [p])
    assert np.array_equal(cold[0], w2[0])


def test_victim_off_is_structural_zero():
    """With the victim cache off the same two-wave trace shows zero
    cross-drain hits (the discriminating counter is victim_hits, not
    prefix_hits, which within-wave live sharing can also bump)."""
    p = _prompt(2)
    eng = _engine(victim=False)
    _run(eng, [p])
    assert eng.scheduler.alloc.in_use == 0
    assert not eng.scheduler.layout._prefix_full
    _run(eng, [p])
    snap = eng.snapshot()["prefix_cache"]
    assert snap["victim_hits"] == 0
    assert "victim_blocks" not in snap  # pool stats only appear when on


def test_prefix_index_follows_block_lifetime():
    """Regression for the index-lifetime bug: entries must outlive their
    drain epoch exactly as long as their blocks do — alive while parked,
    gone once evicted under pressure."""
    eng = _engine(victim=True, num_blocks=10)  # 9 usable blocks
    _run(eng, [_prompt(3)])                    # parks ~3 blocks
    lay = eng.scheduler.layout
    parked = set(lay.victim.blocks)
    assert parked and all(b in lay._block_keys for b in parked)
    # a fat unrelated request forces reclaim of (some) parked blocks;
    # eviction is lazy — only the allocation shortfall is taken
    _run(eng, [_prompt(99, n=40)], max_new=16)
    assert lay.victim_evictions > 0, "pressure did not reclaim parked chains"
    # at drain the index covers exactly the parked blocks: no entry
    # outlived its block (the original bug) and none died early
    assert set(lay._block_keys) == set(lay.victim.blocks)
    assert eng.scheduler.alloc.in_use == len(lay.victim)
    lay.check(set(), 4)


# -- eviction policy --------------------------------------------------------

def _seed_pool(policy):
    """Two single-block tenants' chains: A admitted, revived + re-parked
    (newer stamp AND one recorded hit); B parked in between, never hit."""
    vc = VictimCache(block_bytes=64, policy=policy)
    vc.admit([("", 0, 11)])              # A parks (stamp 1)
    vc.admit([("", 0, 22)])              # B parks (stamp 2)
    vc.record_match([11])
    vc.revive(11)                      # A resumes...
    vc.admit([("", 0, 11)])              # ...and re-parks (stamp 3, 1 hit)
    return vc


def test_weighted_lru_keeps_hot_chain():
    """Weighted LRU evicts the never-hit chain first even though it is
    not the oldest; plain LRU evicts strictly by recency."""
    assert _seed_pool(WeightedLruEviction()).pick(1, exclude=()) == [22]
    assert _seed_pool(LruEviction()).pick(1, exclude=()) == [22]
    # flip recency so the policies disagree: B re-parks last
    for policy, expect in ((WeightedLruEviction(), 22), (LruEviction(), 11)):
        vc = _seed_pool(policy)
        vc.revive(22)
        vc.admit([("", 0, 22)])          # B newest but still zero hits
        assert vc.pick(1, exclude=()) == [expect], policy.name


def test_deeper_pages_evict_first_within_a_chain():
    """Ties broken deepest-page-first so the chain head (most reusable
    prefix) survives longest."""
    vc = VictimCache(block_bytes=64)
    vc.admit([("", 0, 5), ("", 1, 6), ("", 2, 7)])   # one chain, one stamp
    assert vc.pick(2, exclude=()) == [7, 6]


def test_victim_never_preempts_live_request():
    """Under pressure the engine reclaims parked chains instead of
    preempting live requests: a pool sized so wave 2 only fits if wave
    1's parked chain is evicted must finish with zero preemptions."""
    eng = _engine(victim=True, num_blocks=10)
    _run(eng, [_prompt(4)])
    assert len(eng.scheduler.layout.victim) > 0
    _run(eng, [_prompt(5, n=40)], max_new=16)
    stats = eng.stats()
    assert stats["victim_evictions"] > 0
    assert stats["preemptions"] == 0, \
        "idle cached prefix evicted a live request"


def test_make_victim_eviction_registry():
    assert isinstance(make_victim_eviction("lru"), LruEviction)
    assert isinstance(make_victim_eviction("weighted-lru"),
                      WeightedLruEviction)
    custom = LruEviction()
    assert make_victim_eviction(custom) is custom
    with pytest.raises(ValueError, match="not in"):
        make_victim_eviction("nope")


# -- restart persistence ----------------------------------------------------

def test_save_restore_round_trip_bit_identical(tmp_path):
    """Warm pool -> checkpoint -> fresh engine -> restore: the replay
    resolves against restored blocks (victim_hits > 0 on an engine that
    never served the prompts) and tokens match the warm engine's."""
    prompts = [_prompt(6), _prompt(7, n=24)]
    tenants = ["a", "b"]
    e1 = _engine(victim=True)
    warm = _run(e1, prompts, tenants)
    snap1 = e1.snapshot()["prefix_cache"]
    path = os.fspath(tmp_path / "pc.npz")
    e1.save_prefix_cache(path)
    assert os.path.exists(path) and os.path.exists(
        path + ".meta.json")

    e2 = _engine(victim=True)
    e2.restore_prefix_cache(path)
    snap2 = e2.snapshot()["prefix_cache"]
    assert snap2["victim_blocks"] == snap1["victim_blocks"] > 0
    assert snap2["per_tenant_bytes"] == snap1["per_tenant_bytes"]
    e2.scheduler.layout.check(set(), 4)
    replay = _run(e2, prompts, tenants)
    snap3 = e2.snapshot()["prefix_cache"]
    assert snap3["victim_hits"] > 0, snap3
    for a, b in zip(warm, replay):
        assert np.array_equal(a, b), "restored K/V diverged from warm run"


def test_restore_rejects_mismatched_geometry(tmp_path):
    """A checkpoint written under one model/block geometry must refuse
    to load into another instead of silently corrupting the pool."""
    e1 = _engine(victim=True)
    _run(e1, [_prompt(8)])
    path = os.fspath(tmp_path / "pc.npz")
    e1.save_prefix_cache(path)
    e2 = Engine(CFG, PARAMS, EngineConfig(
        max_slots=4, max_len=64, kv_layout="paged", block_size=4,
        num_blocks=48, prefix_cache=True, victim_cache=True,
        greedy=True, seed=0, debug=True))
    with pytest.raises(ValueError, match="block_size"):
        e2.restore_prefix_cache(path)


def test_restore_into_small_pool_degrades_gracefully(tmp_path):
    """Restoring into a pool too small for the full checkpoint loads
    what fits (respecting quotas) and stays invariant-clean."""
    e1 = _engine(victim=True)
    _run(e1, [_prompt(9), _prompt(10, n=32)], ["a", "b"])
    path = os.fspath(tmp_path / "pc.npz")
    e1.save_prefix_cache(path)
    e2 = _engine(victim=True, num_blocks=6)    # 5 usable blocks
    e2.restore_prefix_cache(path)
    lay = e2.scheduler.layout
    assert 0 < len(lay.victim) <= 5
    lay.check(set(), 4)
    _run(e2, [_prompt(9)], ["a"])              # still serves correctly
    lay.check(set(), 4)


# -- tenant quotas and isolation --------------------------------------------

def test_quota_breach_evicts_only_breaching_tenant():
    """Tenant A's budget covers one block; parking a 3-block chain must
    trim A down to budget while B's parked chain is untouched."""
    quota = {"a": BLOCK * ROW_BYTES, "b": 10 * BLOCK * ROW_BYTES}
    eng = _engine(victim=True, tenants=quota)
    _run(eng, [_prompt(11, n=24)], ["b"])      # B parks 3 blocks
    lay = eng.scheduler.layout
    b_blocks = set(lay.victim.blocks)
    _run(eng, [_prompt(12, n=24)], ["a"])      # A parks 3, trimmed to 1
    per = lay.victim.per_tenant_bytes()
    assert per["a"] <= quota["a"], per
    assert set(lay.victim.blocks) >= b_blocks, \
        "quota enforcement evicted another tenant's chains"
    assert lay.victim_evictions == 2
    lay.check(set(), 4)


def test_identical_prompts_never_share_across_tenants():
    """The same token sequence under two tenants must resolve to
    disjoint block sets — a hash hit may never map another tenant's
    K/V — while within a tenant the second request does share."""
    p = _prompt(13)
    eng = _engine(victim=True)
    _run(eng, [p], ["a"])
    lay = eng.scheduler.layout
    a_blocks = set(lay.victim.blocks)
    assert lay.match_prefix(p, tenant="b") == ([], 0), \
        "cross-tenant prefix resolution"
    blks, _ = lay.match_prefix(p, tenant="a")
    assert blks and set(blks) <= a_blocks
    _run(eng, [p], ["b"])
    ab = lay.victim.per_tenant_bytes()
    assert ab.get("a") and ab.get("b")
    tenants = {lay._block_tenant[b] for b in lay.victim.blocks}
    assert tenants == {"a", "b"}
    lay.check(set(), 4)


def test_victim_requires_prefix_cache():
    with pytest.raises(ValueError, match="prefix_cache"):
        Engine(CFG, PARAMS, EngineConfig(
            max_slots=2, max_len=64, kv_layout="paged", block_size=BLOCK,
            num_blocks=16, prefix_cache=False, victim_cache=True))
