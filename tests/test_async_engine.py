"""The thread-safe / asyncio engine surface (background drain mode).

``Engine.start()`` moves the step loop onto a drain thread; these tests
pin down the contract that makes the HTTP server correct:

* handles resolve without the caller ever pumping — ``result()``,
  ``stream()``, per-token callbacks;
* concurrent submissions from many threads all complete, with tokens
  identical to the same requests run caller-pumped (the drain changes
  *who* steps, never *what* is decoded);
* cross-thread cancel stops the stream;
* ``asubmit()``/``astream()``/``aresult()`` work from an event loop;
* caller-pumped ``step()``/``run()`` are refused while the drain owns
  the loop, and work again after ``shutdown()``;
* wall-clock arrival stamping: a request submitted while the drain is
  mid-epoch carries its real elapsed arrival instant (not 0), so TTFT
  on a long-running server measures queueing, not uptime.
"""
from __future__ import annotations

import asyncio
import threading
import time

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import Engine, EngineConfig, Request

KEY = jax.random.PRNGKey(0)


def _tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, KEY)


def _req(cfg, i, plen=8, max_new=6, seed=0, **kw):
    rng = np.random.RandomState(seed + i)
    return Request(i, rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=max_new, **kw)


def test_background_result_and_stream(setup):
    cfg, params = setup
    with Engine(cfg, params, EngineConfig(max_len=64, max_slots=2)) \
            .start() as eng:
        assert eng.running
        h = eng.submit(_req(cfg, 0))
        c = h.result(timeout=120)
        assert c.finish_reason == "length" and len(c.tokens) == 6
        seen = []
        h2 = eng.submit(_req(cfg, 1))
        h2.on_token(seen.append)
        assert list(h2.stream()) == h2.tokens == seen
        assert h2.finish_reason == "length"
    assert not eng.running


def test_background_tokens_match_caller_pumped(setup):
    cfg, params = setup
    reqs = [_req(cfg, i, plen=(8, 12)[i % 2], max_new=4 + i % 3)
            for i in range(6)]
    ref = Engine(cfg, params, EngineConfig(max_len=64, max_slots=2))
    expect = {c.id: c.tokens for c in ref.generate(reqs)}
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=2)).start()
    try:
        handles = [eng.submit(r) for r in reqs]
        for r, h in zip(reqs, handles):
            assert h.result(timeout=120).tokens == expect[r.id]
    finally:
        eng.shutdown()


def test_concurrent_submitters(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=3)).start()
    out, errs = [], []

    def client(base):
        try:
            for k in range(3):
                h = eng.submit(_req(cfg, base * 10 + k, seed=base))
                out.append(h.result(timeout=120))
        except Exception as e:          # noqa: BLE001 — surfaced below
            errs.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    eng.shutdown()
    assert not errs
    assert len(out) == 12
    assert all(c.finish_reason == "length" and len(c.tokens) == 6
               for c in out)
    assert len({c.id for c in out}) == 12


def test_cross_thread_cancel(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=512, max_slots=1)).start()
    try:
        eng.submit(_req(cfg, 99)).result(timeout=120)   # warmup
        h = eng.submit(_req(cfg, 0, max_new=400))
        while not h.tokens:             # let it start decoding
            time.sleep(0.005)
        h.cancel()
        frozen = list(h.tokens)
        c = h.result(timeout=120)
        assert c.finish_reason == "cancelled"
        # cancel() freezes the stream: at most the in-flight step's token
        # lands after the flag, never more
        assert len(c.tokens) <= len(frozen) + 1
        assert len(c.tokens) < 400
    finally:
        eng.shutdown()


def test_step_refused_while_draining(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64)).start()
    try:
        with pytest.raises(RuntimeError, match="drain thread"):
            eng.step()
        with pytest.raises(RuntimeError, match="drain thread"):
            eng.run()
    finally:
        eng.shutdown()
    # caller-pumped surface works again after shutdown
    h = eng.submit(_req(cfg, 0))
    assert h.result().finish_reason == "length"


def test_batch_mode_cannot_start(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, admission="batch"))
    with pytest.raises(ValueError, match="batch"):
        eng.start()


def test_wall_clock_arrival_stamping(setup):
    """Submissions against a mid-epoch drain carry their true elapsed
    arrival instant; TTFT then measures queueing from *submission*."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=512, max_slots=1)).start()
    try:
        eng.submit(_req(cfg, 99)).result(timeout=120)   # warmup + epoch 0
        first = eng.submit(_req(cfg, 0, max_new=200))   # fresh epoch
        while not first.tokens:
            time.sleep(0.005)
        time.sleep(0.05)                # let the epoch age
        late = eng.submit(_req(cfg, 1, max_new=2))
        c1 = late.result(timeout=120)
        c0 = first.result(timeout=120)
        assert c1.arrival_s >= 0.05, \
            f"late submit must carry its elapsed arrival, got {c1.arrival_s}"
        assert c1.first_token_s >= c1.arrival_s
        # TTFT is measured from submission, so it can't exceed the whole
        # elapsed epoch span
        assert c1.ttft_s <= c0.finish_s
    finally:
        eng.shutdown()


def test_asyncio_surface(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=2)).start()

    async def scenario():
        h = await eng.asubmit(_req(cfg, 0))
        c = await h.aresult()
        assert c.finish_reason == "length" and len(c.tokens) == 6
        toks = [t async for t in eng.astream(_req(cfg, 1))]
        assert len(toks) == 6
        # two concurrent streams interleave on one event loop
        async def collect(r):
            return [t async for t in eng.astream(r)]
        a, b = await asyncio.gather(collect(_req(cfg, 2)),
                                    collect(_req(cfg, 3, plen=12)))
        assert len(a) == 6 and len(b) == 6

    try:
        asyncio.run(scenario())
    finally:
        eng.shutdown()


def test_asubmit_requires_drain(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64))

    async def go():
        with pytest.raises(RuntimeError, match="start"):
            await eng.asubmit(_req(cfg, 0))

    asyncio.run(go())
