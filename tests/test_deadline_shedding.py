"""Wall-clock deadline enforcement: the shed path.

EDF admission only *orders* by deadline; ``enforce_deadlines=True``
additionally sheds a request whose absolute due instant
(``arrival_s + deadline_s`` on the engine clock) passes, completing it
with ``finish_reason="timeout"``. Covered here:

* already expired at submit (``deadline_s=0``) — shed before prefill,
  zero tokens;
* expired while queued behind a long-running request on a contended
  slot budget — shed without ever being admitted;
* expired mid-decode — evicted from its active slot, stream frozen at
  the shed instant, slot/blocks released;
* paged layout: shed requests leak no blocks;
* survivors of a contended shed trace stay greedy-token-identical to
  the static-bucket oracle run of the same surviving set;
* enforcement off (the default) keeps deadlines order-only — nothing
  sheds, which is what every pre-existing EDF test relies on.
"""
from __future__ import annotations

import time

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.scheduler import Request

KEY = jax.random.PRNGKey(0)


def _tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, KEY)


def _req(cfg, i, plen=8, max_new=6, seed=0, **kw):
    rng = np.random.RandomState(seed + i)
    return Request(i, rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=max_new, **kw)


def _engine(cfg, params, **kw):
    kw.setdefault("max_len", 64)
    kw.setdefault("max_slots", 1)
    kw.setdefault("admission", "edf")
    kw.setdefault("enforce_deadlines", True)
    return Engine(cfg, params, EngineConfig(**kw))


def test_already_expired_at_submit(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    h = eng.submit(_req(cfg, 0, deadline_s=0.0))
    (c,) = eng.run()
    assert c.finish_reason == "timeout"
    assert c.tokens == [] and h.tokens == []
    assert eng.stats()["sheds"] == 1
    assert eng.stats()["admissions"] == 0, "shed before prefill"


def test_expired_while_queued(setup):
    """One slot, a long request admitted first, a tight-deadline request
    queued behind it: the queued request expires waiting and sheds
    without ever touching a slot."""
    cfg, params = setup
    eng = _engine(cfg, params)
    long = eng.submit(_req(cfg, 0, max_new=24))
    # step until the long request occupies the only slot, then queue the
    # tight-deadline request behind it (EDF would otherwise admit the
    # earlier-due request first)
    while not eng.scheduler.active:
        eng.step()
    tight = eng.submit(_req(cfg, 1, deadline_s=1e-4))
    time.sleep(2e-3)                    # let the queued deadline lapse
    outs = {c.id: c for c in eng.run()}
    assert outs[0].finish_reason == "length"
    assert len(outs[0].tokens) == 24
    assert outs[1].finish_reason == "timeout" and outs[1].tokens == []
    assert tight.tokens == []
    admitted = [e.request_id for e in eng.scheduler.events
                if e.kind == "admit"]
    assert 1 not in admitted, "expired request must shed before prefill"
    assert long.finish_reason == "length"


def test_expired_mid_decode(setup):
    """A generous decode budget with a deadline shorter than the decode
    wall time: the request starts, emits some tokens, then sheds
    mid-decode with the stream frozen and its slot released."""
    cfg, params = setup
    eng = _engine(cfg, params, max_len=512)
    eng.generate([_req(cfg, 99)])       # warmup: compiles prefill/decode
    h = eng.submit(_req(cfg, 0, max_new=400, deadline_s=0.05))
    (c,) = eng.run()
    assert c.finish_reason == "timeout"
    assert 0 < len(c.tokens) < 400, \
        f"expected a mid-decode shed, got {len(c.tokens)} tokens"
    assert h.tokens == c.tokens, "token emitted after the shed"
    sched = eng.scheduler
    assert sched.done and sorted(sched.free) == [0], "slot leak"
    evict = [e for e in sched.events if e.kind == "shed" and e.request_id == 0]
    assert len(evict) == 1 and evict[0].slot == 0


@pytest.mark.parametrize("prefill_chunk", [0, 4])
def test_paged_shed_releases_blocks(setup, prefill_chunk):
    cfg, params = setup
    eng = _engine(cfg, params, max_len=512, max_slots=2, kv_layout="paged",
                  block_size=8, num_blocks=70, prefill_chunk=prefill_chunk,
                  debug=True)
    eng.generate([_req(cfg, 99)])       # warmup
    hs = [eng.submit(_req(cfg, 0, max_new=400, deadline_s=0.04)),
          eng.submit(_req(cfg, 1, deadline_s=0.0)),
          eng.submit(_req(cfg, 2, max_new=4))]
    outs = {c.id: c for c in eng.run()}
    assert outs[0].finish_reason == "timeout"       # mid-decode
    assert outs[1].finish_reason == "timeout"       # at submit
    assert outs[1].tokens == []
    assert outs[2].finish_reason == "length"        # survivor
    assert eng.scheduler.alloc.in_use == 0, "shed leaked blocks"
    assert not eng.scheduler.block_tables.any()
    assert hs[0].tokens == outs[0].tokens


def test_survivors_match_static_oracle(setup):
    """The acceptance-criteria trace: a contended EDF run sheds its
    expired requests as "timeout" while every survivor's greedy tokens
    are bit-identical to the static-bucket oracle decoding the same
    surviving set."""
    cfg, params = setup
    eng = _engine(cfg, params, max_slots=2)
    eng.generate([_req(cfg, 99)])       # warmup so decode wall time is sane
    reqs = []
    for i in range(8):
        # every third request carries an unmeetable deadline on this
        # contended 2-slot budget; the rest are deadline-free survivors
        reqs.append(_req(cfg, i, plen=8 + 2 * (i % 3), max_new=6,
                         deadline_s=1e-4 if i % 3 == 2 else None))
    outs = {c.id: c for c in eng.generate(reqs)}
    shed = {i for i, c in outs.items() if c.finish_reason == "timeout"}
    assert shed == {2, 5}, f"expected the tight-deadline cohort, got {shed}"
    for i in shed:
        # EDF serves the earliest-due first, so the tight requests may
        # start decoding before the shed fires — frozen prefix, never
        # the full budget
        assert len(outs[i].tokens) < reqs[i].max_new_tokens
    survivors = [r for r in reqs if r.id not in shed]
    oracle = Engine(cfg, params, EngineConfig(max_len=64, admission="batch"))
    expect = {c.id: c for c in oracle.generate(survivors)}
    for i, c in expect.items():
        assert outs[i].tokens == c.tokens, \
            f"survivor {i} diverged from the static oracle"
        assert outs[i].finish_reason == c.finish_reason


def test_enforcement_off_never_sheds(setup):
    """The default keeps deadlines order-only (pure EDF): an expired
    deadline is still served — exactly the pre-enforcement behavior the
    conformance matrix and the EDF policy tests rely on."""
    cfg, params = setup
    eng = _engine(cfg, params, enforce_deadlines=False)
    outs = eng.generate([_req(cfg, 0, deadline_s=0.0),
                         _req(cfg, 1, deadline_s=1e-5, max_new=4)])
    assert [c.finish_reason for c in outs] == ["length", "length"]
    assert eng.stats()["sheds"] == 0


def test_batch_mode_rejects_enforcement(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="enforce_deadlines"):
        Engine(cfg, params, EngineConfig(admission="batch",
                                         enforce_deadlines=True))
