"""Per-architecture smoke tests: every assigned arch instantiates a
REDUCED same-family variant (2 layers, d_model <= 256, <= 4 experts) and
runs one forward + one train step on CPU, asserting output shapes and the
absence of NaNs; plus a prefill+decode consistency check against the
full-sequence forward (the serving path must agree with training)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, get_config
from repro.models import transformer as T
from repro.runtime import optim
from repro.runtime.trainstep import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, s=16, labels=True):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        batch["embeds"] = jax.random.normal(
            KEY, (b, cfg.frontend_tokens, cfg.frontend_dim))
    elif cfg.arch_type == "audio":
        batch["embeds"] = jax.random.normal(KEY, (b, s, cfg.frontend_dim))
    if labels:
        total = s + (cfg.frontend_tokens if cfg.arch_type == "vlm" else 0)
        batch["labels"] = jax.random.randint(KEY, (b, total), 0,
                                             cfg.vocab_size)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    return request.param


def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expected = {
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 0, 151936),
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "gemma3_1b": (26, 1152, 4, 1, 6912, 262144),
        "llama3_2_3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 0, 151936),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
        "chatglm3_6b": (28, 4096, 32, 2, 13696, 65024),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected
    assert len(cfg.layer_kinds) == cfg.n_layers


def test_moe_configs():
    q2 = get_config("qwen2_moe_a2_7b").moe
    assert (q2.n_experts, q2.top_k, q2.n_shared_experts,
            q2.d_ff_expert) == (60, 4, 4, 1408)
    q3 = get_config("qwen3_moe_235b_a22b").moe
    assert (q3.n_experts, q3.top_k, q3.n_shared_experts,
            q3.d_ff_expert) == (128, 8, 0, 1536)


def test_smoke_forward_no_nan(arch):
    cfg = get_config(arch).smoke()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = T.init_params(cfg, KEY)
    batch = _batch_for(cfg, labels=False)
    logits, aux = T.forward(params, cfg, batch, train=False)
    total = 16 + (cfg.frontend_tokens if cfg.arch_type == "vlm" else 0)
    assert logits.shape == (2, total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


def test_smoke_train_step_no_nan(arch):
    cfg = get_config(arch).smoke()
    params = T.init_params(cfg, KEY)
    opt = optim.init(params)
    step = make_train_step(cfg, optim.AdamWConfig(lr=1e-3, warmup_steps=1))
    batch = _batch_for(cfg)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(opt2["step"]) == 1
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).smoke()
    params = T.init_params(cfg, KEY)
    b, s = 2, 12
    batch = _batch_for(cfg, b, s, labels=False)
    logits_full, _ = T.forward(params, cfg, batch, train=False)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s - 1]
    lg, cache, clen = T.prefill(params, cfg, pre,
                                max_len=s + cfg.frontend_tokens + 4)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full[:, -2]),
                               rtol=2e-3, atol=2e-3)
    lg2, cache, clen = T.decode_step(params, cfg, batch["tokens"][:, s - 1],
                                     cache, clen)
    np.testing.assert_allclose(np.asarray(lg2),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_cache_is_ring_buffer():
    """Decode through a window-2 local-attn cache twice around the ring and
    compare against the quadratic reference."""
    cfg = get_config("gemma3_1b").smoke()
    assert any(k == "attn_local" for k in cfg.layer_kinds)
    assert cfg.window == 8
    params = T.init_params(cfg, KEY)
    b, s = 1, 24      # > 2x window -> wraps the ring
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    logits_full, _ = T.forward(params, cfg, batch, train=False)
    pre = {"tokens": batch["tokens"][:, :4]}
    lg, cache, clen = T.prefill(params, cfg, pre, max_len=s)
    for t in range(4, s):
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t - 1]),
            rtol=5e-3, atol=5e-3, err_msg=f"t={t}")
        lg, cache, clen = T.decode_step(params, cfg, batch["tokens"][:, t],
                                        cache, clen)


def test_param_count_analytic_close_to_actual(arch):
    cfg = get_config(arch).smoke()
    params = T.init_params(cfg, KEY)
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.35, (actual, analytic)
