"""Observability layer (`runtime.observability`): metrics, traces, wiring.

Four contract groups:

* **metric primitives** — fixed-bucket histogram bucket assignment and
  interpolated percentiles, monotone counter ``sync``, registry
  get-or-create semantics, and exact totals under concurrent observers;
* **Prometheus exposition** — ``render()`` round-trips through
  ``parse_prometheus`` and the cumulative bucket series is monotone;
* **Chrome traces** — the ``Tracer`` produces validating traces
  (snapshot-closing open spans in the export copy only), clock-domain
  mixing on one track is refused, and ``validate_chrome_trace`` rejects
  each malformation it documents;
* **engine wiring** — greedy tokens are bit-identical with observability
  on vs off, ``/metrics``-style text agrees with ``Engine.snapshot()``,
  concurrent submits through a live drain keep counters consistent, and
  batch admission stays raise-free with empty-but-typed snapshots.
"""
from __future__ import annotations

import json
import threading

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.observability import (MODELED, SIZE_BUCKETS,
                                         TIME_BUCKETS_S, Counter, Gauge,
                                         Histogram, MetricsRegistry,
                                         Observability, Tracer,
                                         failover_trace, parse_prometheus,
                                         pipeline_trace, simulator_trace,
                                         validate_chrome_trace)
from repro.serving import Engine, EngineConfig, Request

KEY = jax.random.PRNGKey(0)


def _tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="obs-tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, KEY)


def _reqs(cfg, specs, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(1, cfg.vocab_size, n).astype(np.int32),
                    max_new_tokens=m) for i, (n, m) in enumerate(specs)]


# ---------------------------------------------------------------------------
# histogram math
# ---------------------------------------------------------------------------

def _hist(bounds=(1.0, 2.0, 4.0)):
    return Histogram("h", "", bounds, threading.Lock())


def test_histogram_bucket_edges_inclusive():
    h = _hist()
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 99.0):
        h.observe(v)
    # inclusive upper edges: 1.0 lands in the le=1 bucket, 2.0 in le=2,
    # 4.0 in le=4, 99.0 overflows
    assert h.buckets() == [(1.0, 2), (2.0, 4), (4.0, 5), (float("inf"), 6)]
    assert h.count == 6 and h.min == 0.5 and h.max == 99.0


def test_histogram_single_value_exact_at_every_quantile():
    h = _hist()
    h.observe(1.7)
    for q in (0, 50, 90, 99, 100):
        assert h.percentile(q) == pytest.approx(1.7)
    s = h.summary()
    assert s["count"] == 1 and s["p50"] == pytest.approx(1.7)


def test_histogram_percentiles_interpolate_and_clamp():
    h = _hist(bounds=tuple(float(b) for b in range(1, 101)))
    for v in range(1, 101):
        h.observe(float(v))
    # uniform 1..100: interpolated percentiles track the data within a
    # bucket's width
    assert h.percentile(50) == pytest.approx(50.0, abs=1.0)
    assert h.percentile(90) == pytest.approx(90.0, abs=1.0)
    # clamped to the observed extremes
    assert h.percentile(0) == 1.0 and h.percentile(100) == 100.0


def test_histogram_overflow_percentile_is_observed_max():
    h = _hist(bounds=(1.0,))
    for v in (10.0, 20.0, 30.0):
        h.observe(v)
    assert h.percentile(99) == 30.0


def test_histogram_empty_summary_and_reset():
    h = _hist()
    assert h.summary() == {"count": 0, "sum": 0.0}
    h.observe(2.0)
    assert h.summary()["count"] == 1
    h.reset()
    assert h.summary() == {"count": 0, "sum": 0.0}
    assert h.buckets()[-1][1] == 0


def test_histogram_rejects_unsorted_bounds():
    with pytest.raises(ValueError, match="increasing"):
        _hist(bounds=(2.0, 1.0))
    with pytest.raises(ValueError, match="increasing"):
        _hist(bounds=(1.0, 1.0))


def test_histogram_percentile_range_checked():
    h = _hist()
    h.observe(1.0)
    with pytest.raises(ValueError, match="percentile"):
        h.percentile(101)


# ---------------------------------------------------------------------------
# counters, gauges, registry
# ---------------------------------------------------------------------------

def test_counter_inc_and_monotone_sync():
    c = Counter("c", "", threading.Lock())
    c.inc()
    c.inc(4)
    assert c.value == 5
    c.sync(12)                      # external total overtakes
    assert c.value == 12
    c.sync(3)                       # never goes backwards
    assert c.value == 12


def test_gauge_set_inc_dec():
    g = Gauge("g", "", threading.Lock())
    g.set(5.0)
    g.inc(2.0)
    g.dec(3.0)
    assert g.value == 4.0


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    h1 = reg.histogram("x", TIME_BUCKETS_S)
    assert reg.histogram("x") is h1
    with pytest.raises(TypeError, match="already registered"):
        reg.counter("x")
    snap = reg.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert "x" in snap["histograms"]


def test_registry_reset_histograms_keeps_counters():
    reg = MetricsRegistry()
    reg.counter("c").inc(7)
    reg.histogram("h", (1.0,)).observe(0.5)
    reg.reset_histograms()
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 7
    assert snap["histograms"]["h"]["count"] == 0


def test_registry_concurrent_observers_exact_totals():
    """8 threads x 500 samples through one shared lock: no sample lost,
    no double count."""
    reg = MetricsRegistry()
    h = reg.histogram("lat", (0.5, 1.0))
    c = reg.counter("n")
    n_threads, per = 8, 500

    def work(i):
        for k in range(per):
            h.observe((i + k) % 3 * 0.4)
            c.inc()

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * per
    assert h.count == n_threads * per
    assert h.buckets()[-1][1] == n_threads * per


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

def test_render_parse_round_trip():
    reg = MetricsRegistry()
    reg.counter("repro_things_total", help="things").inc(3)
    reg.gauge("repro_depth").set(2.5)
    h = reg.histogram("repro_lat_seconds", (0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    parsed = parse_prometheus(reg.render())
    assert parsed["counters"]["repro_things_total"] == 3
    assert parsed["gauges"]["repro_depth"] == 2.5
    ph = parsed["histograms"]["repro_lat_seconds"]
    assert ph["count"] == 3 and ph["sum"] == pytest.approx(5.55)
    assert ph["buckets"] == [(0.1, 1), (1.0, 2), (float("inf"), 3)]
    cums = [c for _, c in ph["buckets"]]
    assert cums == sorted(cums)


# ---------------------------------------------------------------------------
# tracer + validator
# ---------------------------------------------------------------------------

def test_tracer_produces_validating_trace():
    tr = Tracer()
    tr.begin("engine", "slot 0", "req 1", 0.0)
    tr.complete("engine", "slot 0", "prefill", 0.0, 0.01)
    tr.instant("engine", "slot 0", "prefix-hit", 0.015)
    tr.end("engine", "slot 0", 0.02)
    tr.async_begin("engine", "queue", "req 2 queued", 2, 0.001)
    tr.async_end("engine", "queue", 2, 0.005)
    trace = tr.chrome_trace()
    n = validate_chrome_trace(trace)
    assert n == tr.event_count()
    # metadata first, then ts-sorted events
    evs = trace["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    rest = [e for e in evs if e["ph"] != "M"]
    assert {e["args"]["name"] for e in meta} >= {"engine", "slot 0", "queue"}
    assert [e["ts"] for e in rest] == sorted(e["ts"] for e in rest)
    assert json.loads(json.dumps(trace)) == trace      # JSON-serializable


def test_tracer_snapshot_closes_open_spans_in_copy_only():
    tr = Tracer()
    tr.begin("engine", "slot 0", "req 9", 0.0)
    t1 = tr.chrome_trace()
    assert validate_chrome_trace(t1) > 0
    closer = [e for e in t1["traceEvents"] if e["ph"] == "E"]
    assert closer and closer[0]["args"]["snapshot_closed"]
    # the live span is still open: ending it later is legal and a new
    # snapshot carries the real E, not a synthetic one
    tr.end("engine", "slot 0", 1.0)
    t2 = tr.chrome_trace()
    assert validate_chrome_trace(t2) > 0
    ends = [e for e in t2["traceEvents"] if e["ph"] == "E"]
    assert len(ends) == 1 and "args" not in ends[0]


def test_tracer_refuses_clock_mixing_per_track():
    tr = Tracer()
    tr.complete("sim", "unit", "a", 0.0, 1.0, clock=MODELED)
    with pytest.raises(ValueError, match="clock"):
        tr.instant("sim", "unit", "b", 2.0)            # wall on modeled track
    # a different track in the same process may use another clock
    tr.instant("sim", "other", "b", 2.0)
    assert validate_chrome_trace(tr.chrome_trace()) > 0


def test_tracer_unmatched_ends_raise():
    tr = Tracer()
    with pytest.raises(RuntimeError, match="no open span"):
        tr.end("p", "t", 0.0)
    with pytest.raises(RuntimeError, match="no open span"):
        tr.async_end("p", "t", 7, 0.0)


def _base_event(**kw):
    ev = {"name": "x", "ph": "X", "pid": 1, "tid": 1, "ts": 1.0, "dur": 1.0,
          "cat": "wall"}
    ev.update(kw)
    return ev


@pytest.mark.parametrize("events,frag", [
    ([{"name": "x", "ph": "X", "pid": 1}], "missing"),
    ([_base_event(ts="soon")], "numeric ts"),
    ([_base_event(ts=5.0), _base_event(ts=1.0)], "out of order"),
    ([_base_event(ph="E", dur=None)], "without matching B"),
    ([_base_event(ph="B")], "unclosed B"),
    ([_base_event(dur=-1.0)], "negative dur"),
    ([_base_event(ph="e", id="7")], "without open 'b'"),
    ([_base_event(ph="b", id="7")], "unclosed async"),
    ([_base_event(ph="?")], "unknown phase"),
    ([_base_event(cat="wall"), _base_event(ts=2.0, cat="modeled")],
     "mixes clocks"),
])
def test_validator_rejects_malformed_traces(events, frag):
    with pytest.raises(ValueError, match=frag):
        validate_chrome_trace({"traceEvents": events})
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})


# ---------------------------------------------------------------------------
# modeled-clock exporters
# ---------------------------------------------------------------------------

class _Firing:
    def __init__(self, unit, actor, start_s, finish_s, idx):
        self.unit, self.actor = unit, actor
        self.start_s, self.finish_s = start_s, finish_s
        self.firing_index, self.modeled_s = idx, finish_s - start_s


class _SimResult:
    def __init__(self, firings):
        self.firings = firings


class _FailoverEvent:
    def __init__(self):
        self.t_fail_s, self.t_detect_s, self.resynth_s = 1.0, 1.5, 0.25
        self.dead_units, self.dead_links = ("server",), ()
        self.mapping_from, self.mapping_to = "half", "all-endpoint"
        self.recovery_latency_s, self.replayed_frames = 0.75, 2


def test_pipeline_trace_and_write(tmp_path):
    from repro.core.synthesis import PipelineSchedule, StageExec
    sched = PipelineSchedule(entries=[
        StageExec(0, "endpoint", 0.0, 0.5),
        StageExec(0, "server", 0.5, 1.0),
        StageExec(1, "endpoint", 0.5, 1.0),    # overlaps frame 0's stage 2
    ])
    obs = Observability(enabled=True)
    assert pipeline_trace(obs.tracer, sched) == 3
    path = tmp_path / "pipeline_trace.json"
    n = obs.write_trace(str(path))
    trace = json.loads(path.read_text())
    assert validate_chrome_trace(trace) == n
    by_thread = {e["args"]["name"] for e in trace["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"endpoint", "server"} <= by_thread


def test_modeled_exporters_share_one_validating_trace():
    tr = Tracer()
    n_sim = simulator_trace(tr, _SimResult([
        _Firing("endpoint", "Embed", 0.0, 0.4, 0),
        _Firing("server", "Head", 0.4, 0.9, 0)]))
    n_fo = failover_trace(tr, [_FailoverEvent()])
    assert (n_sim, n_fo) == (2, 3)
    trace = tr.chrome_trace()
    assert validate_chrome_trace(trace) == tr.event_count()
    cats = {e["cat"] for e in trace["traceEvents"] if e["ph"] != "M"}
    assert cats == {MODELED}
    names = [e["name"] for e in trace["traceEvents"] if e["ph"] == "X"]
    assert {"Embed", "Head", "detection", "resynthesis"} <= set(names)


# ---------------------------------------------------------------------------
# engine wiring
# ---------------------------------------------------------------------------

def test_greedy_tokens_identical_with_observability(setup):
    cfg, params = setup
    specs = [(8, 6), (12, 6), (10, 4)]
    outs = {}
    for on in (False, True):
        eng = Engine(cfg, params, EngineConfig(
            max_len=64, max_slots=2, observability=on))
        outs[on] = [c.tokens for c in eng.generate(_reqs(cfg, specs))]
    assert outs[True] == outs[False]


def test_engine_metrics_agree_with_snapshot(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        max_len=64, max_slots=2, observability=True))
    eng.generate(_reqs(cfg, [(8, 5), (10, 5)]))
    parsed = parse_prometheus(eng.metrics_text())
    snap = eng.snapshot()
    assert snap["observability"]
    for k, v in snap["counters"].items():
        name = f"repro_{k}" if k.endswith("_total") else f"repro_{k}_total"
        assert parsed["counters"][name] == v, k
    hists = snap["metrics"]["histograms"]
    assert hists["repro_ttft_seconds"]["count"] == 2
    assert parsed["histograms"]["repro_ttft_seconds"]["count"] == 2
    # inter-token gaps: every emitted token past each request's first
    expect_gaps = snap["counters"]["tokens_generated"] - 2
    assert hists["repro_inter_token_seconds"]["count"] == expect_gaps
    # engine trace validates and carries both lifecycle span kinds
    assert validate_chrome_trace(eng.trace_json()) > 0


def test_engine_observability_off_is_inert(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, max_slots=2))
    eng.generate(_reqs(cfg, [(8, 4)]))
    snap = eng.snapshot()
    assert not snap["observability"]
    assert snap["metrics"]["histograms"] == {}
    assert eng.trace_json()["traceEvents"] == []
    # counters still mirror into the exposition (derived from events)
    parsed = parse_prometheus(eng.metrics_text())
    assert parsed["counters"]["repro_admissions_total"] == 1


def test_batch_admission_snapshots_raise_free(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, admission="batch"))
    snap = eng.snapshot()
    assert snap["active_slots"] == 0 and snap["kv"] == {}
    assert set(snap["counters"]) and all(
        v == 0 for v in snap["counters"].values())
    assert eng.stats()["admissions"] == 0
    assert eng.kv_stats() == {}
    parse_prometheus(eng.metrics_text())    # renders without raising


def test_concurrent_submit_consistent_counters(setup):
    """Submits racing the live drain thread: every request completes,
    counters and histogram counts agree with the submitted total, and
    the trace still validates."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        max_len=64, max_slots=2, observability=True))
    n_threads, per = 4, 3
    handles, errs = [], []
    lock = threading.Lock()

    def client(i):
        try:
            rng = np.random.RandomState(i)
            for k in range(per):
                r = Request(i * per + k,
                            rng.randint(1, cfg.vocab_size, 8).astype(np.int32),
                            max_new_tokens=3)
                with lock:
                    handles.append(eng.submit(r))
        except Exception as e:          # noqa: BLE001 — surfaced below
            errs.append(e)

    with eng.start():
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        outs = [h.result(timeout=120) for h in handles]
    assert all(c.finish_reason == "length" and len(c.tokens) == 3
               for c in outs)
    snap = eng.snapshot()
    total = n_threads * per
    assert snap["counters"]["requests_submitted"] == total
    assert snap["counters"]["admissions"] == total
    assert snap["counters"]["tokens_generated"] == total * 3
    hists = snap["metrics"]["histograms"]
    assert hists["repro_ttft_seconds"]["count"] == total
    assert hists["repro_queue_wait_seconds"]["count"] == total
    assert validate_chrome_trace(eng.trace_json()) > 0


def test_property_interleaved_observers():
    """ANY interleaving of histogram observes and counter incs across
    two workers keeps registry totals exact (hypothesis; skipped on the
    fast lane)."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need hypothesis (see "
                             "nightly lane)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 1), st.floats(0.0, 10.0)),
                    max_size=60))
    def prop(ops):
        reg = MetricsRegistry()
        h = reg.histogram("h", (1.0, 5.0))
        c = reg.counter("c")
        half = len(ops) // 2
        done = []

        def run(chunk):
            for kind, v in chunk:
                (h.observe(v) if kind else c.inc())
            done.append(1)

        ts = [threading.Thread(target=run, args=(chunk,))
              for chunk in (ops[:half], ops[half:])]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert len(done) == 2
        n_obs = sum(1 for kind, _ in ops if kind)
        assert h.count == n_obs and h.buckets()[-1][1] == n_obs
        assert c.value == len(ops) - n_obs

    prop()


def test_size_buckets_cover_prompt_scale():
    assert SIZE_BUCKETS[0] == 1 and SIZE_BUCKETS[-1] >= 4096
    assert list(SIZE_BUCKETS) == sorted(SIZE_BUCKETS)
