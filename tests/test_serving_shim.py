"""Regression suite for the deprecated ``ServeEngine`` shim.

The shim's whole contract is "legacy call sites keep working unchanged
until removal": every legacy kwarg maps onto the ``EngineConfig`` field
the migration table names, the legacy mode-conditional ``ValueError``s
fire with their original messages, and construction emits exactly one
``DeprecationWarning`` naming the replacement. These used to be
exercised only incidentally (old tests, examples); pinning them here
means the shim can't silently drift while it lives.
"""
from __future__ import annotations

import warnings

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.policies import BatchAdmission, FifoAdmission
from repro.runtime.scheduler import Request
from repro.runtime.serving import ServeEngine


def _tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _reqs(cfg, specs, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, cfg.vocab_size, p).astype(np.int32),
                    max_new_tokens=m) for i, (p, m) in enumerate(specs)]


SPECS = [(8, 6), (12, 4), (8, 9), (5, 1)]


def test_shim_warns_exactly_once_with_migration_pointer(setup):
    cfg, params = setup
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        ServeEngine(cfg, params, max_len=64)
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, "construction must warn exactly once"
    msg = str(deps[0].message)
    # the warning is the migration doc: it must name the replacement and
    # the kwarg mapping
    for needle in ("ServeEngine is deprecated", "EngineConfig",
                   "admission='batch'", "admission='fifo'",
                   "kv_layout='paged'"):
        assert needle in msg, f"warning lost its pointer: {needle!r}"
    assert deps[0].filename == __file__, \
        "stacklevel must point at the caller, not the shim"


@pytest.mark.parametrize("legacy_kw,expect", [
    (dict(), dict(admission_cls=BatchAdmission, kv_layout="slotted")),
    (dict(mode="static-bucket"), dict(admission_cls=BatchAdmission)),
    (dict(mode="continuous", max_slots=3),
     dict(admission_cls=FifoAdmission, max_slots=3)),
    (dict(mode="continuous", paged=True, block_size=8, num_blocks=20,
          watermark=2),
     dict(kv_layout="paged", block_size=8, num_blocks=20, watermark=2)),
    (dict(mode="continuous", prefill_chunk=4), dict(prefill_chunk=4)),
    (dict(greedy=False, temperature=0.7, seed=5),
     dict(greedy=False, temperature=0.7, seed=5)),
], ids=["default", "static", "continuous", "paged", "chunked", "sampling"])
def test_legacy_kwargs_map_onto_engine_config(setup, legacy_kw, expect):
    """Field-by-field: the shim builds the Engine the migration table
    promises for each legacy kwarg spelling."""
    cfg, params = setup
    with pytest.warns(DeprecationWarning):
        shim = ServeEngine(cfg, params, max_len=48, **legacy_kw)
    ec = shim.engine.config
    assert ec.max_len == 48
    for key, val in expect.items():
        if key == "admission_cls":
            assert isinstance(shim.engine.admission, val)
        else:
            assert getattr(ec, key) == val, key
    # the shim exposes the legacy attribute surface
    assert shim.cfg is cfg and shim.params is params
    assert shim.scheduler is shim.engine.scheduler


def test_shim_output_matches_new_facade(setup):
    cfg, params = setup
    reqs = _reqs(cfg, SPECS)
    ref = Engine(cfg, params, EngineConfig(max_len=64, admission="batch")) \
        .generate(reqs)
    with pytest.warns(DeprecationWarning):
        legacy_static = ServeEngine(cfg, params, max_len=64)
    with pytest.warns(DeprecationWarning):
        legacy_paged = ServeEngine(cfg, params, max_len=64,
                                   mode="continuous", max_slots=2,
                                   paged=True, block_size=8)
    assert [c.tokens for c in legacy_static.generate(reqs)] == \
        [c.tokens for c in ref]
    assert [c.tokens for c in legacy_paged.generate(reqs)] == \
        [c.tokens for c in ref]


def test_legacy_value_errors_preserved(setup):
    """The original mode-conditional errors, verbatim triggers: callers
    relying on them (and on their messages) must see identical
    behavior."""
    cfg, params = setup
    reqs = _reqs(cfg, SPECS[:2])
    with pytest.raises(ValueError, match="mode 'bogus' not in"):
        with pytest.warns(DeprecationWarning):
            ServeEngine(cfg, params, mode="bogus")
    with pytest.raises(ValueError, match="require .*mode='continuous'"):
        with pytest.warns(DeprecationWarning):
            ServeEngine(cfg, params, paged=True)
    with pytest.raises(ValueError, match="require .*mode='continuous'"):
        with pytest.warns(DeprecationWarning):
            ServeEngine(cfg, params, prefill_chunk=4)
    with pytest.warns(DeprecationWarning):
        static = ServeEngine(cfg, params, max_len=64)
    with pytest.raises(ValueError, match="arrivals requires "
                                         "mode='continuous'"):
        static.generate(reqs, arrivals=[0.0, 0.0])
    with pytest.raises(ValueError, match="on_completion requires "
                                         "mode='continuous'"):
        static.generate(reqs, on_completion=lambda c: None)
    # continuous mode accepts both (no spurious new errors)
    with pytest.warns(DeprecationWarning):
        cont = ServeEngine(cfg, params, max_len=64, mode="continuous",
                           max_slots=2)
    seen = []
    outs = cont.generate(reqs, arrivals=[0.0, 0.0],
                         on_completion=seen.append)
    assert len(outs) == len(reqs) and len(seen) == len(reqs)


def test_shim_rejects_oversized_requests_like_engine(setup):
    """Admission validation flows through the shim unchanged."""
    cfg, params = setup
    with pytest.warns(DeprecationWarning):
        eng = ServeEngine(cfg, params, max_len=16)
    with pytest.raises(ValueError, match="exceeding max_len"):
        eng.generate([Request(0, np.zeros(14, np.int32), max_new_tokens=8)])
