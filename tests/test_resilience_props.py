"""Property-based failover tests (hypothesis).

The companion fault-tolerance paper's contract, stated as a property: for
*any* partition-point mapping of a chain graph and *any* single-unit
failure injected after frame k acked, the failover run's frames 0..k are
bit-exactly the failure-free run's (they were committed before the
failure and are never recomputed), and — because a single-unit fallback
mapping always survives a single-unit failure — the whole stream is
eventually served bit-exactly on the re-mapped program.
"""
from __future__ import annotations

import pytest

from repro.core import synthesize
from repro.runtime.resilience import FailureTrace
from test_resilience import (all_on, chain_graph, partition,
                             two_unit_platform, _controller)

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see "
    "requirements-dev.txt); the fast lane skips them")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_failure_after_frame_k_preserves_prefix(data):
    n_mid = data.draw(st.integers(1, 4), label="n_mid")
    n_frames = data.draw(st.integers(3, 7), label="n_frames")
    muls = data.draw(st.lists(st.integers(2, 99), min_size=n_mid,
                              max_size=n_mid), label="muls")
    g = chain_graph(n_mid, muls)
    n_actors = len(g.actors)
    pp = data.draw(st.integers(1, n_actors - 1), label="pp")
    k = data.draw(st.integers(0, n_frames - 2), label="k")
    dead = data.draw(st.sampled_from(["endpoint", "server"]), label="dead")
    pm = two_unit_platform()
    primary = partition(g, pp)
    fallbacks = [all_on(g, "endpoint"), all_on(g, "server")]
    frames = [{"Src": 7 * i + 1} for i in range(n_frames)]

    nominal, nrep = _controller(g, primary, fallbacks, pm).serve(frames)
    assert nrep.num_failovers == 0

    # Fail strictly between frame k's ack and frame k+1's ack on the
    # nominal timeline (one window => controller timeline == pipeline's).
    done = synthesize(g, primary).run_pipelined(
        frames, platform=pm)[1].frame_done_s
    t_fail = (done[k] + done[k + 1]) / 2
    assert done[k] < t_fail < done[k + 1]

    ctl = _controller(g, primary, fallbacks, pm)
    outs, rep = ctl.serve(
        frames, failures=FailureTrace().kill_unit(dead, at=t_fail))
    # frames 0..k acked before the failure: bit-exact and never replayed
    for i in range(k + 1):
        assert outs[i]["Snk"] == nominal[i]["Snk"], f"frame {i} diverged"
        assert i not in rep.frames_replayed
    # a viable single-unit fallback exists, so the whole stream completes
    # bit-exactly
    assert not rep.frames_unserved
    for i in range(n_frames):
        assert outs[i]["Snk"] == nominal[i]["Snk"]
    assert rep.num_failovers == 1
    assert dead not in ctl.mapping.units_used()
