"""HTTP front end (`runtime.server`): routes, streaming, backpressure.

Runs a real ``EngineServer`` on an ephemeral port (tiny model, warmup
on) and exercises it over actual sockets with ``http.client``:

* ``/health/live`` / ``/health/ready`` / ``/status`` probe contracts;
* ``/generate`` non-streaming vs streaming return identical tokens, and
  both match an in-process caller-pumped engine run of the same prompt
  (the HTTP layer is transport, not policy);
* chunked NDJSON framing: one token per line, terminal ``done`` line
  carries the completion;
* 400 on malformed bodies, 404 on unknown routes;
* 429 + Retry-After once ``max_inflight`` requests are open
  (bounded-admission backpressure);
* wall-clock deadline shed surfaces as ``finish_reason="timeout"``
  through the HTTP response;
* ``/metrics`` Prometheus exposition agrees with ``/status`` whether
  observability is on or off, and ``/trace`` always serves a valid
  (possibly empty) Chrome trace.
"""
from __future__ import annotations

import http.client
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import (Engine, EngineConfig, EngineServer, Request,
                           ServerConfig, parse_prometheus,
                           validate_chrome_trace)

KEY = jax.random.PRNGKey(0)


def _tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, KEY)


@pytest.fixture(scope="module")
def server(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        max_len=512, max_slots=2, admission="edf", enforce_deadlines=True))
    with EngineServer(eng, ServerConfig(port=0, max_inflight=3)) as srv:
        yield srv


def _request(srv, method, path, body=None):
    conn = http.client.HTTPConnection(srv.config.host, srv.port, timeout=120)
    try:
        conn.request(method, path,
                     json.dumps(body) if body is not None else None,
                     {"Content-Type": "application/json"})
        r = conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()
    finally:
        conn.close()


def _generate(srv, body):
    status, _, raw = _request(srv, "POST", "/generate", body)
    return status, (json.loads(raw) if raw else None)


def test_health_and_status(server):
    status, _, raw = _request(server, "GET", "/health/live")
    assert status == 200 and json.loads(raw)["status"] == "live"
    status, _, raw = _request(server, "GET", "/health/ready")
    assert status == 200 and json.loads(raw)["status"] == "ready"
    status, _, raw = _request(server, "GET", "/status")
    st = json.loads(raw)
    assert status == 200
    assert st["ready"] and st["max_inflight"] == 3
    assert {"inflight", "queue_depth", "active_slots",
            "kv", "counters"} <= set(st)
    assert st["counters"]["admissions"] >= 1        # the warmup request


def test_unknown_routes(server):
    assert _request(server, "GET", "/nope")[0] == 404
    assert _request(server, "POST", "/nope")[0] == 404


@pytest.mark.parametrize("body,frag", [
    ({}, "prompt"),
    ({"prompt": "hi"}, "prompt"),
    ({"prompt": []}, "prompt"),
    ({"prompt": [1, 2], "max_new_tokens": 0}, "max_new_tokens"),
    ({"prompt": [1, 2], "deadline_s": "soon"}, "deadline_s"),
    ({"prompt": [1, 2], "eos": "x"}, "eos"),
])
def test_bad_requests(server, body, frag):
    status, out = _generate(server, body)
    assert status == 400 and frag in out["error"]


def test_generate_matches_inprocess(server, setup):
    cfg, params = setup
    prompt = [int(t) for t in
              np.random.RandomState(5).randint(1, 64, 10)]
    status, out = _generate(server, {"prompt": prompt, "max_new_tokens": 7})
    assert status == 200
    assert out["finish_reason"] == "length" and len(out["tokens"]) == 7
    assert out["ttft_s"] >= 0 and out["latency_s"] >= out["ttft_s"]
    # oracle: same prompt through a fresh caller-pumped engine
    ref = Engine(cfg, params, EngineConfig(max_len=512, max_slots=2))
    (c,) = ref.generate([Request(0, np.asarray(prompt, np.int32),
                                 max_new_tokens=7)])
    assert out["tokens"] == [int(t) for t in c.tokens]


def test_streaming_ndjson(server):
    prompt = [int(t) for t in np.random.RandomState(6).randint(1, 64, 8)]
    conn = http.client.HTTPConnection(server.config.host, server.port,
                                      timeout=120)
    try:
        conn.request("POST", "/generate",
                     json.dumps({"prompt": prompt, "max_new_tokens": 5,
                                 "stream": True}),
                     {"Content-Type": "application/json"})
        r = conn.getresponse()
        assert r.status == 200
        assert r.getheader("Content-Type") == "application/x-ndjson"
        lines = [json.loads(ln) for ln in r.read().splitlines()
                 if ln.strip()]
    finally:
        conn.close()
    toks = [ln["token"] for ln in lines if "token" in ln]
    final = lines[-1]
    assert final["done"] and final["finish_reason"] == "length"
    assert final["tokens"] == toks and len(toks) == 5
    # non-streamed run of the identical prompt matches token for token
    _, out = _generate(server, {"prompt": prompt, "max_new_tokens": 5})
    assert out["tokens"] == toks


def test_deadline_shed_over_http(server):
    status, out = _generate(server, {"prompt": [1, 2, 3], "deadline_s": 0.0,
                                     "max_new_tokens": 8})
    assert status == 200
    assert out["finish_reason"] == "timeout" and out["tokens"] == []


def test_backpressure_429(server):
    """Fill the admission bound (3) with slow streaming requests, then
    verify the next one bounces with 429 + Retry-After and that capacity
    comes back once the stream completes."""
    prompt = [int(t) for t in np.random.RandomState(7).randint(1, 64, 8)]
    conns = []
    try:
        for _ in range(3):
            c = http.client.HTTPConnection(server.config.host, server.port,
                                           timeout=120)
            c.request("POST", "/generate",
                      json.dumps({"prompt": prompt, "max_new_tokens": 300,
                                  "stream": True}),
                      {"Content-Type": "application/json"})
            conns.append(c)
        # wait until all three are actually admitted server-side
        deadline = time.time() + 30
        while time.time() < deadline:
            st = json.loads(_request(server, "GET", "/status")[2])
            if st["inflight"] >= 3:
                break
            time.sleep(0.01)
        status, headers, raw = _request(
            server, "POST", "/generate",
            {"prompt": prompt, "max_new_tokens": 2})
        assert status == 429
        assert "admission queue full" in json.loads(raw)["error"]
        assert headers.get("Retry-After") == "1"
    finally:
        for c in conns:
            c.getresponse().read()      # drain to completion
            c.close()
    # capacity released: the same request is admitted now
    status, out = _generate(server, {"prompt": prompt, "max_new_tokens": 2})
    assert status == 200 and out["finish_reason"] == "length"


def test_concurrent_http_clients(server):
    results = []
    errs = []

    def client(i):
        try:
            prompt = [int(t)
                      for t in np.random.RandomState(i).randint(1, 64, 8)]
            status, out = _generate(
                server, {"prompt": prompt, "max_new_tokens": 4})
            results.append((status, out))
        except Exception as e:          # noqa: BLE001 — surfaced below
            errs.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert [s for s, _ in results] == [200, 200, 200]
    assert all(len(o["tokens"]) == 4 for _, o in results)


def test_metrics_without_observability(server):
    """Counters are mirrored from the scheduler's event log, so
    /metrics works even with observability off — it just carries no
    histogram samples."""
    status, headers, raw = _request(server, "GET", "/metrics")
    assert status == 200
    assert headers["Content-Type"].startswith("text/plain")
    parsed = parse_prometheus(raw.decode())
    st = json.loads(_request(server, "GET", "/status")[2])
    assert not st["observability"]
    assert parsed["counters"]["repro_admissions_total"] \
        == st["counters"]["admissions"]
    assert parsed["counters"]["repro_steps_total"] == st["counters"]["steps"]
    assert parsed["gauges"]["repro_http_max_inflight"] == 3
    ttft = parsed["histograms"].get("repro_ttft_seconds")
    assert ttft is None or ttft["count"] == 0


def test_trace_empty_without_observability(server):
    status, _, raw = _request(server, "GET", "/trace")
    assert status == 200
    trace = json.loads(raw)
    assert validate_chrome_trace(trace) == 0
    assert trace["traceEvents"] == []


@pytest.fixture(scope="module")
def obs_server(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        max_len=512, max_slots=2, observability=True))
    with EngineServer(eng, ServerConfig(port=0, max_inflight=3)) as srv:
        yield srv


def test_metrics_with_observability(obs_server):
    prompt = [int(t) for t in np.random.RandomState(9).randint(1, 64, 8)]
    status, out = _generate(obs_server,
                            {"prompt": prompt, "max_new_tokens": 6})
    assert status == 200 and len(out["tokens"]) == 6

    status, _, raw = _request(obs_server, "GET", "/metrics")
    assert status == 200
    parsed = parse_prometheus(raw.decode())
    st = json.loads(_request(obs_server, "GET", "/status")[2])
    assert st["observability"]
    # exposition and snapshot describe the same state
    assert parsed["counters"]["repro_admissions_total"] \
        == st["counters"]["admissions"]
    snap_hists = st["metrics"]["histograms"]
    for name in ("repro_ttft_seconds", "repro_inter_token_seconds",
                 "repro_step_duration_seconds", "repro_queue_wait_seconds"):
        assert parsed["histograms"][name]["count"] \
            == snap_hists[name]["count"], name
    assert snap_hists["repro_ttft_seconds"]["count"] >= 1
    # cumulative buckets are monotone and end at the total count
    buckets = parsed["histograms"]["repro_ttft_seconds"]["buckets"]
    cums = [c for _, c in buckets]
    assert cums == sorted(cums)
    assert cums[-1] == parsed["histograms"]["repro_ttft_seconds"]["count"]


def test_trace_with_observability(obs_server):
    prompt = [int(t) for t in np.random.RandomState(10).randint(1, 64, 8)]
    status, _ = _generate(obs_server, {"prompt": prompt,
                                       "max_new_tokens": 4})
    assert status == 200
    status, _, raw = _request(obs_server, "GET", "/trace")
    assert status == 200
    trace = json.loads(raw)
    assert validate_chrome_trace(trace) > 0
    names = {e.get("name") for e in trace["traceEvents"]}
    # request lifecycle spans and step slices are present
    assert any(isinstance(n, str) and n.startswith("req ") for n in names)
    assert any(isinstance(n, str) and n.startswith("step ") for n in names)
    # the engine drain records on the wall clock only
    cats = {e.get("cat") for e in trace["traceEvents"]
            if e.get("ph") != "M"}
    assert cats <= {"wall"}


def test_server_rejects_batch_engine(setup):
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(max_len=64, admission="batch"))
    with pytest.raises(ValueError, match="batch"):
        EngineServer(eng)


def test_multi_tenant_victim_cache_over_http(setup):
    """The prefix-cache service over the wire: two tenants post the same
    prompt twice each; /status exposes per-tenant pool occupancy, the
    second round registers cross-request victim hits, and a bad tenant
    field is a 400."""
    cfg, params = setup
    eng = Engine(cfg, params, EngineConfig(
        max_len=64, max_slots=2, kv_layout="paged", block_size=8,
        num_blocks=24, prefix_cache=True, victim_cache=True,
        prefix_cache_tenants={"acme": 1 << 20, "globex": 1 << 20},
        debug=True))
    prompt = [int(t) for t in np.random.RandomState(11).randint(1, 64, 20)]
    with EngineServer(eng, ServerConfig(port=0, max_inflight=3)) as srv:
        status, out = _generate(srv, {"prompt": prompt, "tenant": 7})
        assert status == 400 and "tenant" in out["error"]
        first = {}
        for tenant in ("acme", "globex"):
            status, out = _generate(srv, {"prompt": prompt,
                                          "max_new_tokens": 6,
                                          "tenant": tenant})
            assert status == 200
            first[tenant] = out["tokens"]
        # identical prompts under different tenants: same greedy tokens,
        # but the pool holds a separate copy per namespace
        assert first["acme"] == first["globex"]
        status, _, raw = _request(srv, "GET", "/status")
        pc = json.loads(raw)["prefix_cache"]
        assert status == 200 and pc["enabled"] and pc["victim_cache"]
        per = pc["per_tenant_bytes"]
        assert per.get("acme", 0) > 0 and per.get("globex", 0) > 0
        assert pc["tenant_quotas"] == {"acme": 1 << 20, "globex": 1 << 20}
        before = pc["victim_hits"]
        for tenant in ("acme", "globex"):
            status, out = _generate(srv, {"prompt": prompt,
                                          "max_new_tokens": 6,
                                          "tenant": tenant})
            assert status == 200
            assert out["tokens"] == first[tenant], \
                "cache hit changed the tokens"
        status, _, raw = _request(srv, "GET", "/status")
        pc = json.loads(raw)["prefix_cache"]
        assert pc["victim_hits"] > before, \
            "second round never hit the parked chains"
        assert pc["prefill_tokens_saved"] > 0 and pc["bytes_saved"] > 0
