"""Property-based prefill→decode handoff accounting (hypothesis).

The multi-unit execution core's contract, stated as properties:

* the prefill→decode **handoff is zero-copy bookkeeping**: across ANY
  interleaving of admissions, chunked prefills, growth preemptions
  (tight pool), and ``SlotFailure`` injections on a disaggregated
  topology (dedicated prefill unit + pipelined decode stages), the
  ``BlockAllocator``'s books still balance — no block leaks or
  double-frees just because K/V crossed a unit boundary, every request
  gets its full token budget, and the drained pool is whole;
* handoffs are counted exactly once per admission (one-shot, prefix
  tail, chunked finish, and re-admission after preemption/failure all
  included), and no slot's modeled ready time survives the drain;
* unit topologies move **modeled time only**: the token streams are
  bit-identical to a clean single-unit, failure-free run of the same
  requests;
* at the ``ExecutionCore`` level, ANY op interleaving keeps the clock
  accounting exact: per-unit busy time sums to the sequential work,
  the makespan never exceeds it, and ``release`` always clears a
  slot's pending ready time.
"""
from __future__ import annotations

import math

import jax
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.scheduler import (ContinuousScheduler, ExecutionCore,
                                     Request, SchedulerConfig, SlotFailure)

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see "
    "requirements-dev.txt); the fast lane skips them")
from hypothesis import given, settings, strategies as st  # noqa: E402

CFG = ModelConfig(
    name="handoff-props", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
    param_dtype="float32", attn_chunk=16, remat=False)
PARAMS = T.init_params(CFG, jax.random.PRNGKey(0))
# few distinct prompt lengths => the one-shot prefill compiles stay cached
PROMPT_LENS = (4, 6, 8, 12)


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_property_handoff_books_balance(data):
    """Random workloads + random ``SlotFailure`` injections over a tight
    paged pool on a disaggregated 3-unit topology (1 prefill unit, 2
    pipelined decode stages): the allocator's books balance at drain,
    handoffs count admissions exactly, and tokens match a clean
    single-unit run bit for bit."""
    rng = np.random.RandomState(data.draw(st.integers(0, 2 ** 16),
                                          label="seed"))
    n_req = data.draw(st.integers(2, 6), label="n_req")
    chunk = data.draw(st.sampled_from([0, 4]), label="prefill_chunk")
    # worst case: 12 prompt + 6 new tokens - 1 -> 17 rows -> 5 blocks of
    # 4; a tight pool forces growth preemption with 2-3 slots busy
    num_blocks = data.draw(st.integers(6, 14), label="num_blocks")
    placement = data.draw(st.sampled_from(["round-robin", "least-loaded"]),
                          label="placement")
    reqs = [Request(i, rng.randint(0, CFG.vocab_size,
                                   PROMPT_LENS[i % len(PROMPT_LENS)]
                                   ).astype(np.int32),
                    max_new_tokens=int(rng.randint(1, 7)))
            for i in range(n_req)]
    n_fail = data.draw(st.integers(0, 3), label="n_fail")
    failures = [SlotFailure(step=data.draw(st.integers(0, 20),
                                           label=f"fail_step{i}"),
                            slots=data.draw(st.sampled_from(
                                [None, (0,), (0, 1)]), label=f"fail_slots{i}"))
                for i in range(n_fail)]
    sched = ContinuousScheduler(
        CFG, PARAMS, SchedulerConfig(max_slots=3, max_len=24, paged=True,
                                     block_size=4, num_blocks=num_blocks,
                                     prefill_chunk=chunk, debug=True,
                                     units=3, prefill_units=1,
                                     decode_stages=2, placement=placement),
        failures=failures)
    for r in reqs:
        sched.submit(r)
    outs = sched.run()
    assert [o.id for o in outs] == list(range(n_req)), "request dropped"
    for o, r in zip(outs, reqs):
        assert len(o.tokens) == r.max_new_tokens
    # the pool comes home whole despite every K/V crossing units
    sched.alloc.check()
    assert sched.alloc.in_use == 0, "leaked blocks across the handoff"
    assert sched.alloc.available == sched.alloc.capacity
    assert not sched.block_tables.any()
    # handoff bookkeeping drains with the pool
    core = sched.core
    assert core.slot_ready == {}, "stale K/V-ready time survived the drain"
    assert core.handoffs == sched.stats()["admissions"]
    s = core.summary()
    assert s["kv_handoffs"] == core.handoffs
    assert s["modeled_sequential_s"] > 0
    assert s["modeled_makespan_s"] <= s["modeled_sequential_s"] + 1e-9
    # units move modeled time only: bit-identical to a roomy,
    # failure-free single-unit drain of the same requests
    ref = ContinuousScheduler(
        CFG, PARAMS, SchedulerConfig(max_slots=3, max_len=24, paged=True,
                                     block_size=4, num_blocks=32))
    for r in reqs:
        ref.submit(Request(r.id, r.prompt.copy(),
                           max_new_tokens=r.max_new_tokens))
    ref_outs = ref.run()
    assert {o.id: o.tokens for o in outs} == \
        {o.id: o.tokens for o in ref_outs}


@settings(max_examples=200, deadline=None)
@given(data=st.data())
def test_property_execution_core_clock_accounting(data):
    """ANY prefill/handoff/decode/release interleaving on a random unit
    topology keeps the modeled accounting exact: per-unit busy sums to
    the sequential work, the makespan never exceeds it (and never moves
    backwards), and released slots carry no ready time."""
    units = data.draw(st.integers(1, 5), label="units")
    prefill_units = data.draw(st.integers(0, units - 1),
                              label="prefill_units")
    decode_stages = data.draw(st.integers(1, units - prefill_units),
                              label="decode_stages")
    s = SchedulerConfig(units=units, prefill_units=prefill_units,
                        decode_stages=decode_stages,
                        placement=data.draw(st.sampled_from(
                            ["round-robin", "least-loaded"]),
                            label="placement"),
                        prefill_sec_per_token=1e-3,
                        decode_sec_per_token=1e-3)
    core = ExecutionCore(s)
    live: set = set()
    last_makespan = 0.0
    for _ in range(data.draw(st.integers(0, 30), label="n_ops")):
        op = data.draw(st.sampled_from(
            ["prefill", "handoff", "decode", "release"]), label="op")
        slot = data.draw(st.integers(0, 3), label="slot")
        if op == "prefill":
            finish = core.prefill(slot, data.draw(st.integers(1, 16),
                                                  label="tokens"))
            assert core.slot_ready[slot] == finish
            live.add(slot)
        elif op == "handoff":
            core.handoff(slot, blocks=data.draw(st.integers(0, 4),
                                                label="blocks"))
        elif op == "decode":
            lanes = data.draw(st.lists(st.integers(0, 3), min_size=0,
                                       max_size=4, unique=True),
                              label="slots")
            core.decode_step(sorted(lanes))
            live -= set(lanes)          # decode consumes the ready times
        else:
            core.release(slot)
            assert slot not in core.slot_ready
            live.discard(slot)
        assert set(core.slot_ready) <= live
        assert math.isclose(sum(core.clocks.busy_s.values()),
                            core.sequential_s, rel_tol=1e-9, abs_tol=1e-12)
        assert core.makespan_s <= core.sequential_s + 1e-9
        assert core.makespan_s >= last_makespan, "a clock moved backwards"
        last_makespan = core.makespan_s
    assert core.speedup >= 1.0 - 1e-9 or core.sequential_s == 0
