"""Multi-unit execution core: unit clocks, executors, placement,
stage-partitioned decode, and the lifted pipeline synthesis.

Covers the two halves of the multi-unit story separately from the
conformance matrix (which pins end-to-end token identity):

* modeled time — ``UnitClocks`` / ``ExecutionCore`` recurrences
  (disaggregation overlaps prefill with decode, pipelined decode
  overlaps stages, ``units=1`` degenerates to serialized work),
  placement policies, and the scheduler/engine integration surface;
* computation — ``decode_step_staged`` is bit-identical to
  ``decode_step`` for every stage count, and ``synthesize``/
  ``run_pipelined`` now accept mappings that revisit a unit
  (endpoint → server → endpoint), contending for one physical clock.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Link, Mapping, PlatformGraph, PlatformModel,
                        ProcessingUnit, Simulator, synthesize)
from repro.core.clocks import UnitClocks
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.policies import (LeastLoadedPlacement, RoundRobinPlacement,
                                    make_placement)
from repro.runtime.scheduler import (ExecutionCore, Request, SchedulerConfig)

from test_core_graph import chain_graph

CFG = ModelConfig(
    name="mu", arch_type="dense", n_layers=3, d_model=48, n_heads=4,
    n_kv_heads=2, d_ff=96, vocab_size=96, dtype="float32",
    param_dtype="float32", attn_chunk=16, remat=False,
    layer_pattern=("attn", "attn"), tie_embeddings=True)


def _sched(**kw):
    base = dict(max_slots=4, max_len=32, prefill_sec_per_token=1e-3,
                decode_sec_per_token=1e-3)
    base.update(kw)
    return SchedulerConfig(**base)


# ---------------------------------------------------------------------------
# UnitClocks
# ---------------------------------------------------------------------------

class TestUnitClocks:
    def test_charge_recurrence(self):
        c = UnitClocks()
        s0, f0 = c.charge("u", 0.0, 2.0)
        assert (s0, f0) == (0.0, 2.0)
        # ready before the clock: starts when the unit frees up
        s1, f1 = c.charge("u", 1.0, 1.0)
        assert (s1, f1) == (2.0, 3.0)
        # ready after the clock: the unit idles until the input lands
        s2, f2 = c.charge("u", 5.0, 1.0)
        assert (s2, f2) == (5.0, 6.0)
        assert c.makespan_s == 6.0
        assert c.busy_s["u"] == pytest.approx(4.0)  # 2 + 1 + 1, no idle

    def test_set_never_goes_backwards(self):
        c = UnitClocks()
        c.set("u", 5.0)
        c.set("u", 3.0)
        assert c.now("u") == 5.0


# ---------------------------------------------------------------------------
# ExecutionCore
# ---------------------------------------------------------------------------

class TestExecutionCore:
    def test_single_unit_degenerate(self):
        """units=1 (every existing config): one clock, makespan == the
        serialized work sum, speedup exactly 1."""
        core = ExecutionCore(_sched())
        core.prefill(0, 10)
        core.handoff(0)
        for _ in range(5):
            core.decode_step([0])
        assert core.makespan_s == pytest.approx(core.sequential_s)
        assert core.speedup == pytest.approx(1.0)
        assert [u.name for u in core.units] == ["decode0"]

    def test_disaggregation_overlaps_prefill_with_decode(self):
        """A dedicated prefill unit absorbs prompt bursts while the
        decode unit streams tokens: the modeled makespan beats the
        serialized sum."""
        core = ExecutionCore(_sched(units=2, prefill_units=1))
        for slot in range(4):
            core.prefill(slot, 20)
            core.handoff(slot)
            active = list(range(slot + 1))
            for _ in range(10):
                core.decode_step(active)
        assert core.makespan_s < core.sequential_s
        assert core.speedup > 1.3
        busy = core.clocks.busy_s
        assert busy["prefill0"] > 0 and busy["decode0"] > 0

    def test_prefill_chunks_chain_per_slot(self):
        """Chunks of one slot never overlap each other even with two
        prefill units: the slot's ready time chains them."""
        core = ExecutionCore(_sched(units=3, prefill_units=2))
        f1 = core.prefill(0, 10)
        f2 = core.prefill(0, 10)          # placed round-robin on prefill1
        assert f2 == pytest.approx(f1 + 10 * core.prefill_spt)

    def test_pipelined_decode_splits_stage_cost(self):
        """K stages each charge 1/K of the step; with one lane per stage
        the pipeline fills and the makespan stays below K serialized
        steps."""
        one = ExecutionCore(_sched())
        two = ExecutionCore(_sched(units=2, decode_stages=2))
        slots = [0, 1, 2, 3]
        for core in (one, two):
            for s in slots:
                core.prefill(s, 1)
                core.handoff(s)
            for _ in range(20):
                core.decode_step(slots)
        # same total work, overlapped stages -> strictly faster
        assert two.sequential_s == pytest.approx(one.sequential_s)
        assert two.makespan_s < one.makespan_s
        assert two.speedup > 1.0

    def test_handoff_is_bookkeeping_only(self):
        core = ExecutionCore(_sched(units=2, prefill_units=1))
        core.prefill(0, 8)
        before = dict(core.clocks.busy_s)
        core.handoff(0, blocks=3)
        assert core.clocks.busy_s == before     # no time charged
        assert core.handoffs == 1

    def test_release_clears_slot_state(self):
        core = ExecutionCore(_sched())
        core.prefill(0, 8)
        core.release(0)
        assert 0 not in core.slot_ready

    def test_summary_schema(self):
        core = ExecutionCore(_sched(units=3, prefill_units=1,
                                    decode_stages=2))
        core.prefill(0, 4)
        core.decode_step([0])
        s = core.summary()
        assert {u["role"] for u in s["units"]} == {"prefill", "decode"}
        assert len(s["units"]) == 3
        assert s["decode_stages"] == 2
        assert s["modeled_makespan_s"] > 0
        assert s["modeled_sequential_s"] >= s["modeled_makespan_s"] - 1e-12
        assert s["kv_handoffs"] == 0

    @pytest.mark.parametrize("kw,msg", [
        (dict(units=0), "units"),
        (dict(units=2, prefill_units=2), "prefill_units"),
        (dict(units=2, prefill_units=1, decode_stages=2), "decode_stages"),
    ])
    def test_invalid_topologies_rejected(self, kw, msg):
        with pytest.raises(ValueError, match=msg):
            ExecutionCore(_sched(**kw))


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------

class _FakeExec:
    def __init__(self, name, busy):
        self.name, self.busy_s = name, busy


class TestPlacement:
    def test_round_robin_cycles(self):
        p = RoundRobinPlacement()
        execs = [_FakeExec("a", 0.0), _FakeExec("b", 0.0)]
        assert [p.pick(execs).name for _ in range(4)] == ["a", "b", "a", "b"]

    def test_least_loaded_picks_min_busy(self):
        p = LeastLoadedPlacement()
        execs = [_FakeExec("a", 5.0), _FakeExec("b", 1.0)]
        assert p.pick(execs).name == "b"

    def test_factory_resolves_names(self):
        assert isinstance(make_placement("round-robin"), RoundRobinPlacement)
        assert isinstance(make_placement("least-loaded"), LeastLoadedPlacement)
        with pytest.raises(ValueError, match="placement policy"):
            make_placement("nope")


# ---------------------------------------------------------------------------
# stage-partitioned decode step
# ---------------------------------------------------------------------------

class TestStagedDecode:
    @pytest.mark.parametrize("stages", [1, 2, 3])
    def test_bit_identical_to_decode_step(self, stages):
        params = T.init_params(CFG, jax.random.PRNGKey(0))
        batch = {"tokens": (jnp.arange(6, dtype=jnp.int32)[None] % 17)}
        logits, cache, clen = T.prefill(params, CFG, batch, max_len=32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        l0, c0, n0 = T.decode_step(params, CFG, tok, cache, clen)
        l1, c1, n1 = T.decode_step_staged(params, CFG, tok, cache, clen,
                                          num_stages=stages)
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
        for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(n0), np.asarray(n1))

    def test_stage_bounds_cover_depth_contiguously(self):
        total = CFG.n_periods + len(CFG.remainder_kinds)
        for k in range(1, 5):
            cuts = T.decode_stage_bounds(CFG, k)
            assert cuts[0] == 0 and cuts[-1] == total
            assert cuts == sorted(cuts)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

class TestEngineIntegration:
    def test_unit_stats_in_snapshot_and_identity(self):
        params = T.init_params(CFG, jax.random.PRNGKey(0))
        reqs = [Request(i, (np.arange(4 + i) % CFG.vocab_size)
                        .astype(np.int32), max_new_tokens=4)
                for i in range(4)]
        ref = Engine(CFG, params, EngineConfig(
            max_len=32, admission="batch")).generate(
                [Request(r.id, r.prompt.copy(), max_new_tokens=4)
                 for r in reqs])
        eng = Engine(CFG, params, EngineConfig(
            max_len=32, max_slots=2, units=3, prefill_units=1,
            decode_stages=2, placement="least-loaded"))
        outs = eng.generate(reqs)
        assert [c.tokens for c in outs] == [c.tokens for c in ref]
        units = eng.snapshot()["units"]
        assert units["kv_handoffs"] == len(reqs)
        assert units["modeled_makespan_s"] > 0
        assert {u["name"] for u in units["units"]} == \
            {"decode0", "decode1", "prefill0"}

    def test_unit_trace_tracks_modeled_clock_only(self):
        """With observability on, a non-trivial topology traces per-unit
        timelines into a dedicated "units" process on the MODELED clock
        (one thread per unit, never mixed with the engine's wall-clock
        tracks), and the combined trace still validates. A single-unit
        engine emits no unit track at all — its default trace stays
        wall-clock-only (tests/test_server.py pins that)."""
        from repro.runtime.observability import validate_chrome_trace
        params = T.init_params(CFG, jax.random.PRNGKey(0))
        reqs = [Request(i, (np.arange(6) % CFG.vocab_size)
                        .astype(np.int32), max_new_tokens=3)
                for i in range(3)]
        eng = Engine(CFG, params, EngineConfig(
            max_len=32, max_slots=2, units=3, prefill_units=1,
            decode_stages=2, observability=True))
        eng.generate(reqs)
        trace = eng.trace_json()
        assert validate_chrome_trace(trace) > 0
        pids = {m["pid"]: m["args"]["name"]
                for m in trace["traceEvents"]
                if m.get("ph") == "M" and m.get("name") == "process_name"}
        unit_pids = {p for p, n in pids.items() if n == "units"}
        assert unit_pids, "no per-unit trace process"
        ev = [e for e in trace["traceEvents"]
              if e.get("pid") in unit_pids and e.get("ph") != "M"]
        assert ev and {e["cat"] for e in ev} == {"modeled"}
        names = {e["name"] for e in ev}
        assert any(n.startswith("prefill") for n in names)
        assert "kv-handoff" in names
        single = Engine(CFG, params, EngineConfig(
            max_len=32, max_slots=2, observability=True))
        single.generate([Request(9, (np.arange(6) % CFG.vocab_size)
                                 .astype(np.int32), max_new_tokens=3)])
        strace = single.trace_json()
        spids = {m["pid"] for m in strace["traceEvents"]
                 if m.get("ph") == "M" and m.get("name") == "process_name"
                 and m["args"]["name"] == "units"}
        assert not spids, "single-unit engine must not open a units track"

    def test_batch_admission_rejects_multi_unit(self):
        params = T.init_params(CFG, jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="multi-unit"):
            Engine(CFG, params, EngineConfig(admission="batch", units=2))

    def test_cli_flags_round_trip(self):
        import argparse
        ap = argparse.ArgumentParser()
        EngineConfig.add_cli_args(ap)
        args = ap.parse_args(["--units", "3", "--prefill-units", "1",
                              "--decode-stages", "2",
                              "--placement", "least-loaded"])
        c = EngineConfig.from_args(args)
        assert (c.units, c.prefill_units, c.decode_stages) == (3, 1, 2)
        assert c.placement == "least-loaded"


# ---------------------------------------------------------------------------
# lifted pipeline synthesis (a unit may appear in several segments)
# ---------------------------------------------------------------------------

class TestSynthesisRevisit:
    def _offload_mapping(self, g):
        """endpoint -> server -> endpoint: the offload shape the old
        each-unit-appears-once splitter rejected."""
        return Mapping("m", {"src": "ep", "a0": "ep", "a1": "sv",
                             "a2": "ep", "snk": "ep"})

    def test_split_opens_segment_per_revisit(self):
        g = chain_graph(3)
        prog = synthesize(g, self._offload_mapping(g))
        assert [s.unit for s in prog.stages] == ["ep", "sv", "ep"]
        assert [s.key for s in prog.stages] == ["ep", "sv", "ep#1"]
        # both boundary crossings carry a channel
        assert len(prog.channels) == 2

    def test_run_local_matches_simulator(self):
        g = chain_graph(3)
        prog = synthesize(g, self._offload_mapping(g))
        feed = np.arange(4, dtype=np.float32)
        out = prog.run_local({"src": feed})
        sim = Simulator(g).run(1, source_inputs={"src": [feed]})
        np.testing.assert_allclose(out["snk"][0], sim.outputs["snk"][0])

    def test_run_pipelined_revisits_contend_for_one_clock(self):
        g = chain_graph(3)
        for a, flops in (("a0", 1e9), ("a1", 1e9), ("a2", 1e9)):
            g.actors[a].cost_flops = flops
        pg = PlatformGraph("p")
        pg.add_unit(ProcessingUnit("ep", flops=1e9))
        pg.add_unit(ProcessingUnit("sv", flops=1e9))
        pg.add_link(Link("ep", "sv", bandwidth=1e9))
        pg.add_link(Link("sv", "ep", bandwidth=1e9))
        m = Mapping("m", {"src": "ep", "a0": "ep", "a1": "sv",
                          "a2": "ep", "snk": "ep"}, pg)
        prog = synthesize(g, m)
        frames = [{"src": np.full(4, i, np.float32)} for i in range(4)]
        sinks, sched = prog.run_pipelined(frames, platform=PlatformModel(pg))
        for i, s in enumerate(sinks):
            np.testing.assert_allclose(s["snk"][0], np.full(4, i + 3.0))
        # both ep segments charged ONE physical clock: ep busy time is
        # the sum over its two stages, and entries exist for both
        ep_entries = [e for e in sched.entries if e.unit == "ep"]
        assert len(ep_entries) == 2 * len(frames)
        assert sched.unit_busy_s["ep"] == pytest.approx(
            sum(e.finish_s - e.start_s for e in ep_entries))
        # pipelining across 2 physical units still beats sequential
        assert sched.makespan_s <= sched.sequential_s + 1e-12

    def test_same_unit_channel_carries_no_comm_bytes(self):
        """A skip connection between two segments of ONE unit (ep seg 0
        feeds both the server segment and the later ep#1 segment) is an
        in-memory hand-off: the channel exists so the data flows, but no
        modeled bytes cross a device boundary."""
        from test_core_graph import _sink, _source, _spa
        from repro.core import Graph
        g = Graph("skip")
        src = g.add_actor(_source("src"))
        a = g.add_actor(_spa("a", n_out=2, fn=lambda ts: ts[0] + 1.0))
        b = g.add_actor(_spa("b", fn=lambda ts: ts[0] * 2.0))
        c = g.add_actor(_spa("c", n_in=2, fn=lambda ts: ts[0] + ts[1]))
        snk = g.add_actor(_sink("snk"))
        g.connect(src.port("out"), a.port("in"))
        g.connect(a.port("out0"), b.port("in"))
        g.connect(a.port("out1"), c.port("in1"))
        g.connect(b.port("out"), c.port("in0"))
        g.connect(c.port("out"), snk.port("in"))
        m = Mapping("m", {"src": "ep", "a": "ep", "b": "sv",
                          "c": "ep", "snk": "ep"})
        prog = synthesize(g, m)
        assert [s.key for s in prog.stages] == ["ep", "sv", "ep#1"]
        same = [ch for ch in prog.channels if ch.src_unit == ch.dst_unit]
        cross = [ch for ch in prog.channels if ch.src_unit != ch.dst_unit]
        assert len(same) == 1 and len(cross) == 2    # the a->c skip is free
        assert prog.comm_bytes_per_iteration() == \
            sum(ch.token_bytes for ch in cross)
        # and the data still flows through the in-memory channel:
        # snk = 2*(x+1) + (x+1) = 3x+3
        feed = np.arange(4, dtype=np.float32)
        np.testing.assert_allclose(prog.run_local({"src": feed})["snk"][0],
                                   3 * feed + 3)
