"""The roofline analysis hinges on the HLO collective-bytes parser:
test it on synthetic HLO text covering loop-trip weighting, nesting,
tuples, and shape-byte math. (Import is safe: dryrun.py only sets
XLA_FLAGS, which pytest workers ignore since jax is already initialized
by earlier imports in the suite.)"""
from __future__ import annotations

import sys


def _parse(text):
    # import without tripping device-count init order issues
    import repro.launch.dryrun as dr
    return dr.collective_bytes(text)


HLO = """
HloModule jit_step

%cond.1 (arg: (s32[], f32[8])) -> pred[] {
  %iv = s32[] get-tuple-element(%arg), index=0
  %trip = s32[] constant(5)
  ROOT %cmp = pred[] compare(%iv, %trip), direction=LT
}

%body.1 (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %x = f32[8]{0} get-tuple-element(%arg), index=1
  %ar = f32[8]{0} all-reduce(%x), channel_id=1
  ROOT %t = (s32[], f32[8]) tuple(%iv2, %ar)
}

%cond.2 (arg2: (s32[], f32[4])) -> pred[] {
  %iv3 = s32[] get-tuple-element(%arg2), index=0
  %trip2 = s32[] constant(3)
  ROOT %cmp2 = pred[] compare(%iv3, %trip2), direction=LT
}

%body.2 (arg2: (s32[], f32[4])) -> (s32[], f32[4]) {
  %y = f32[4]{0} get-tuple-element(%arg2), index=1
  %inner = (s32[], f32[8]) while(%w0), condition=%cond.1, body=%body.1
  %ag = bf16[16,4]{1,0} all-gather(%yy), channel_id=2
  ROOT %t2 = (s32[], f32[4]) tuple(%iv4, %y)
}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %outer = (s32[], f32[4]) while(%init), condition=%cond.2, body=%body.2
  %top = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(%a, %b), channel_id=3
  ROOT %r = f32[4]{0} copy(%p0)
}
"""


def test_collective_bytes_loop_weighting_and_shapes():
    out = _parse(HLO)
    # all-reduce f32[8] = 32 B, inside body.1 (trip 5) nested in body.2
    # (trip 3) -> 32 * 15 = 480
    assert out["all-reduce"] == 480.0
    # all-gather bf16[16,4] = 128 B, inside body.2 (trip 3) -> 384
    assert out["all-gather"] == 384.0
    # tuple all-to-all at top level: 2 * f32[2,2] = 32 B
    assert out["all-to-all"] == 32.0
    assert out["total"] == 480.0 + 384.0 + 32.0


def test_shape_bytes():
    import repro.launch.dryrun as dr
    assert dr._shape_bytes("f32[2,3]{1,0}") == 24
    assert dr._shape_bytes("(bf16[4]{0}, s32[2]{0})") == 8 + 8
    assert dr._shape_bytes("pred[10]{0}") == 10
    assert dr._shape_bytes("f32[]") == 0 or dr._shape_bytes("f32[]") == 4
