"""Continuous-batching scheduler + pipelined execution tests:

* admission/eviction ordering — FIFO admission, slots freed on eviction
  and reused by later requests;
* KV-slot reuse correctness — the shared-slot decode batch emits exactly
  the static-bucket path's greedy tokens, across mixed prompt lengths,
  eos stops and slot churn;
* paged KV cache + chunked prefill — every layout/admission combination
  (paged, chunked, paged+chunked, oversubscribed pool with growth
  preemption) stays token-identical to the static path, admission waits
  instead of over-committing the pool, and block accounting balances
  (freed exactly once) across evict/fail/preempt;
* pipelined modeled clocks — per-unit start times are monotone, every
  firing respects data availability, and the pipelined makespan beats
  sequential execution of the same stages while staying >= the bottleneck
  bound.
"""
from __future__ import annotations

from collections import defaultdict

import jax
import numpy as np
import pytest

from repro.core import (Link, Mapping, PlatformGraph, PlatformModel,
                        ProcessingUnit, Simulator)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.scheduler import (ContinuousScheduler, SchedulerConfig,
                                     SlotFailure)
from repro.runtime.serving import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _tiny_cfg(n_layers: int = 2) -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=n_layers, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, KEY)


def _mixed_requests(cfg, specs, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=mnew)
            for i, (plen, mnew) in enumerate(specs)]


# ---------------------------------------------------------------------------
# KV-slot reuse correctness
# ---------------------------------------------------------------------------

def test_continuous_matches_static_bucket_tokens(setup):
    """More requests than slots, four distinct prompt lengths, varying
    decode lengths: the slot-reusing shared batch must emit the exact
    greedy tokens of the per-bucket baseline."""
    cfg, params = setup
    reqs = _mixed_requests(cfg, [(8, 6), (12, 4), (8, 9), (5, 1), (12, 7),
                                 (16, 5), (7, 3), (9, 8), (8, 2), (16, 6)])
    static = ServeEngine(cfg, params, max_len=64).generate(reqs)
    cont = ServeEngine(cfg, params, max_len=64, mode="continuous",
                       max_slots=4).generate(reqs)
    assert [c.id for c in cont] == [s.id for s in static]
    for s, c in zip(static, cont):
        assert c.tokens == s.tokens, f"request {s.id} diverged"


def test_continuous_respects_eos(setup):
    cfg, params = setup
    reqs = _mixed_requests(cfg, [(8, 12), (10, 12), (6, 12)])
    static = ServeEngine(cfg, params, max_len=64).generate(reqs)
    # pick an eos that actually occurs mid-stream for request 0
    eos = static[0].tokens[3]
    for r in reqs:
        r.eos = eos
    s2 = ServeEngine(cfg, params, max_len=64).generate(reqs)
    c2 = ServeEngine(cfg, params, max_len=64, mode="continuous",
                     max_slots=2).generate(reqs)
    assert [c.tokens for c in c2] == [s.tokens for s in s2]
    assert len(s2[0].tokens) < 12   # eos actually truncated


# ---------------------------------------------------------------------------
# paged KV cache + chunked prefill
# ---------------------------------------------------------------------------

MIXED_SPECS = [(8, 6), (12, 4), (8, 9), (5, 1), (12, 7),
               (16, 5), (7, 3), (9, 8), (8, 2), (16, 6)]


@pytest.mark.parametrize("kw", [
    dict(paged=True, block_size=8),
    dict(prefill_chunk=4),
    dict(paged=True, block_size=8, prefill_chunk=4),
    dict(paged=True, block_size=4, num_blocks=16),   # oversubscribed pool
], ids=["paged", "chunked", "paged+chunked", "paged-tight"])
def test_paged_and_chunked_match_static_tokens(setup, kw):
    """Every cache-layout/admission combination — paged blocks, chunked
    prefill, both, and an oversubscribed pool that forces growth
    preemption — must emit the static-bucket path's exact greedy tokens,
    with slot/block invariants asserted at every step boundary."""
    cfg, params = setup
    reqs = _mixed_requests(cfg, MIXED_SPECS)
    static = ServeEngine(cfg, params, max_len=64).generate(reqs)
    sched = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=4, max_len=64, debug=True,
                                     **kw))
    for r in reqs:
        sched.submit(r)
    outs = sched.run()
    assert [c.id for c in outs] == [s.id for s in static]
    for s, c in zip(static, outs):
        assert c.tokens == s.tokens, f"request {s.id} diverged"
    if kw.get("paged"):
        # every block returned to the pool exactly once
        assert sched.alloc.in_use == 0
        assert sched.alloc.available == sched.alloc.capacity
        assert not sched.block_tables.any()


def test_paged_admission_waits_when_pool_exhausted(setup):
    """A pool that fits ~one request's worst case at a time must
    serialize admissions (no over-commit) and still serve everything:
    the set of concurrently admitted requests never needs more blocks
    than the pool holds."""
    cfg, params = setup
    specs = [(8, 4)] * 5                 # worst case 11 rows -> 3 blocks
    sched = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=4, max_len=32, paged=True,
                                     block_size=4, num_blocks=5, debug=True))
    for r in _mixed_requests(cfg, specs):
        sched.submit(r)
    outs = sched.run()
    assert [len(o.tokens) for o in outs] == [m for _, m in specs]
    live = set()
    peak = 0
    for e in sched.events:
        if e.kind == "admit":
            live.add(e.request_id)
        elif e.kind in ("evict", "fail", "preempt"):
            live.discard(e.request_id)
        peak = max(peak, len(live))
    assert peak <= 2, f"over-committed pool: {peak} concurrent requests"
    assert sched.alloc.in_use == 0


def test_growth_can_preempt_inflight_chunked_prefill(setup):
    """A pool dried out partly by a half-prefilled prompt's blocks must
    still let an older request's decode growth make progress: the
    in-flight chunked prefill is a preemption candidate like any active
    slot, not an invisible block holder that crashes run()."""
    cfg, params = setup
    # capacity 7 blocks of 2 rows. Request 0 (2-row prompt, 12 new
    # tokens, worst case 7 blocks) is decoding and growing a block every
    # 2 steps while request 1's 10-row prompt (5 blocks, admitted
    # upfront) spends 5 iterations in 2-token prefill chunks — the pool
    # runs dry at request 0's second growth, mid-prefill.
    sched = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=2, max_len=14, paged=True,
                                     block_size=2, num_blocks=8,
                                     prefill_chunk=2, debug=True))
    rng = np.random.RandomState(0)
    sched.submit(Request(0, rng.randint(0, cfg.vocab_size, 2)
                         .astype(np.int32), max_new_tokens=12))
    sched.submit(Request(1, rng.randint(0, cfg.vocab_size, 10)
                         .astype(np.int32), max_new_tokens=2))
    outs = sched.run()
    assert [len(o.tokens) for o in outs] == [12, 2]
    preempted = [e for e in sched.events if e.kind == "preempt"]
    assert preempted and preempted[0].request_id == 1
    assert sched.alloc.in_use == 0


def test_paged_rejects_configs_with_no_global_attention(setup):
    """Subquadratic configs are exempt from the max_len rows bound, so
    paged growth could index past the block table; they also have no
    global-attn K/V to page. The combination is rejected up front."""
    cfg, params = setup
    import dataclasses
    local = dataclasses.replace(cfg, layer_pattern=("attn_local",), window=8)
    with pytest.raises(ValueError, match="paged KV cache pages"):
        ContinuousScheduler(local, params,
                            SchedulerConfig(max_slots=2, paged=True))


def test_chunked_prefill_matches_one_shot(setup):
    """Chunked admission is a pure scheduling change: the same workload
    prefilled 4 tokens at a time must emit the one-shot path's exact
    greedy tokens (and actually run chunked: prompts longer than one
    chunk, interleaved with live decodes)."""
    cfg, params = setup
    one_shot = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=3, max_len=64))
    chunked = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=3, max_len=64,
                                     prefill_chunk=4, debug=True))
    for sched in (one_shot, chunked):
        for r in _mixed_requests(cfg, MIXED_SPECS):
            sched.submit(r)
    a, b = one_shot.run(), chunked.run()
    assert [c.tokens for c in a] == [c.tokens for c in b]


def test_evicted_slot_state_is_zeroed(setup):
    """No stale host-side mirrors after a drain: cache_len, last-token
    and block-table rows of freed slots are all zero (the invariant that
    used to rot silently when only cache_len was reset)."""
    cfg, params = setup
    sched = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=2, max_len=64, paged=True,
                                     block_size=8, debug=True),
        failures=[SlotFailure(step=2, slots=(1,))])
    for r in _mixed_requests(cfg, MIXED_SPECS[:5]):
        sched.submit(r)
    sched.run()
    assert not sched.cache_len.any()
    assert not sched.tokens.any()
    assert not sched.block_tables.any()
    assert sched.alloc.in_use == 0


def test_run_is_reentrant_and_keeps_pending_failures(setup):
    """A failure scheduled past the first drain's final step must fire in
    a later run() — the injected list is tracked with a cursor, not
    consumed destructively — and both drains stay bit-identical to the
    static path."""
    cfg, params = setup
    specs_a, specs_b = MIXED_SPECS[:3], MIXED_SPECS[3:6]
    static = ServeEngine(cfg, params, max_len=64).generate(
        _mixed_requests(cfg, specs_a + specs_b))
    sched = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=4, max_len=64, debug=True),
        failures=[SlotFailure(step=10 ** 6),    # never due: must survive
                  SlotFailure(step=12, slots=(0,))])
    reqs = _mixed_requests(cfg, specs_a + specs_b)
    for r in reqs[:3]:
        sched.submit(r)
    first = sched.run()
    steps_after_first = sched.step_count
    for r in reqs[3:]:
        sched.submit(r)
    second = sched.run()
    outs = sorted(first + second, key=lambda c: c.id)
    assert [c.tokens for c in outs] == [s.tokens for s in static]
    # the step-12 failure was consumed by whichever drain reached step 12
    # (the second, unless the first ran long), and the far-future one is
    # still pending — not dropped with the first drain's state
    assert steps_after_first < sched.step_count >= 12
    assert sched._failure_pos == 1
    assert sched.failures[sched._failure_pos].step == 10 ** 6


# ---------------------------------------------------------------------------
# admission / eviction ordering
# ---------------------------------------------------------------------------

def test_admission_is_fifo_and_eviction_frees_slots(setup):
    cfg, params = setup
    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=2, max_len=64))
    reqs = _mixed_requests(cfg, [(8, 2), (8, 6), (8, 3), (8, 4), (8, 1)])
    for r in reqs:
        sched.submit(r)
    outs = sched.run()
    assert [o.id for o in outs] == [0, 1, 2, 3, 4]
    admits = [e for e in sched.events if e.kind == "admit"]
    evicts = [e for e in sched.events if e.kind == "evict"]
    # FIFO: admission order == submission order even with slot contention
    assert [e.request_id for e in admits] == [0, 1, 2, 3, 4]
    assert len(evicts) == len(reqs)
    # every late admission reuses a slot somebody vacated first
    assert {e.slot for e in admits} == {0, 1}
    for a in admits[2:]:
        freed = [e for e in evicts if e.slot == a.slot and e.t_s <= a.t_s]
        assert freed, f"admission of {a.request_id} into occupied slot"
    # eviction happens exactly when the request's budget is spent
    for o in outs:
        assert len(o.tokens) == reqs[o.id].max_new_tokens


def test_overflowing_request_rejected(setup):
    cfg, params = setup
    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=2, max_len=16))
    with pytest.raises(ValueError, match="exceeds max_len"):
        sched.submit(Request(0, np.zeros(32, np.int32)))
    # prompt fits but prompt + decode budget would wrap the KV ring
    with pytest.raises(ValueError, match="exceeding max_len"):
        sched.submit(Request(1, np.zeros(14, np.int32), max_new_tokens=8))
    # exactly at capacity is fine: 14 + 3 - 1 == 16
    sched.submit(Request(2, np.zeros(14, np.int32), max_new_tokens=3))
    (out,) = sched.run()
    assert len(out.tokens) == 3


def test_static_path_rejects_overflow_identically(setup):
    """Both modes must agree on admission: a request the continuous
    scheduler rejects for KV-ring overflow can't silently wrap (and
    corrupt) on the static path either."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=16)
    with pytest.raises(ValueError, match="exceeding max_len"):
        eng.generate([Request(0, np.zeros(14, np.int32), max_new_tokens=8)])


def test_capped_cache_exempt_from_overflow_guard(setup):
    """max_cache_len caps the global-attention ring on purpose — the
    guard must not reject generations that slide past it."""
    cfg, params = setup
    import dataclasses
    capped = dataclasses.replace(cfg, max_cache_len=8)
    eng = ServeEngine(capped, params, max_len=16)
    outs = eng.generate([Request(0, np.zeros(8, np.int32),
                                 max_new_tokens=12)])
    assert len(outs[0].tokens) == 12


def test_arrivals_length_mismatch_rejected(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64, mode="continuous",
                      max_slots=2)
    reqs = _mixed_requests(cfg, [(8, 2), (8, 2)])
    with pytest.raises(ValueError, match="arrivals"):
        eng.generate(reqs, arrivals=[0.0])


def test_arrival_times_produce_waiting(setup):
    """A request arriving later must not be admitted before its arrival
    instant (open-loop Poisson workloads rely on this)."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64, mode="continuous",
                      max_slots=4)
    reqs = _mixed_requests(cfg, [(8, 4), (8, 4)])
    outs = eng.generate(reqs, arrivals=[0.0, 0.05])
    byid = {o.id: o for o in outs}
    assert byid[1].first_token_s >= 0.05
    assert byid[1].ttft_s >= 0.0


# ---------------------------------------------------------------------------
# failure injection: requests on failed slots are re-queued, not dropped
# ---------------------------------------------------------------------------

def test_slot_failure_requeues_not_drops(setup):
    """Mid-decode slot loss: affected requests go back to the head of the
    admission queue and re-prefill; every request (affected or not) must
    emit greedy tokens bit-identical to the failure-free run."""
    cfg, params = setup
    specs = [(8, 6), (12, 4), (8, 9), (5, 5), (12, 7)]
    ref_sched = ContinuousScheduler(cfg, params,
                                    SchedulerConfig(max_slots=2, max_len=64))
    for r in _mixed_requests(cfg, specs):
        ref_sched.submit(r)
    ref = ref_sched.run()

    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=2, max_len=64),
                                failures=[SlotFailure(step=3, slots=(0,))])
    for r in _mixed_requests(cfg, specs):
        sched.submit(r)
    out = sched.run()

    fails = [e for e in sched.events if e.kind == "fail"]
    assert fails, "injected failure never applied"
    assert [c.id for c in out] == [c.id for c in ref], "requests dropped"
    for a, b in zip(ref, out):
        assert a.tokens == b.tokens, f"request {a.id} diverged after requeue"
    # the victim was re-admitted (two admits), budget fully served
    victim = fails[0].request_id
    admits = [e.request_id for e in sched.events if e.kind == "admit"]
    assert admits.count(victim) == 2
    assert len(out[victim].tokens) == specs[victim][1]


def test_whole_unit_failure_requeues_every_active_request(setup):
    """slots=None models whole-unit loss: every active request re-queues
    in FIFO (arrival) order and the stream still completes bit-exactly."""
    cfg, params = setup
    specs = [(8, 5), (12, 5), (8, 5), (16, 5)]
    ref = ServeEngine(cfg, params, max_len=64).generate(
        _mixed_requests(cfg, specs))
    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=4, max_len=64),
                                failures=[SlotFailure(step=1)])
    for r in _mixed_requests(cfg, specs):
        sched.submit(r)
    out = sched.run()
    fails = [e for e in sched.events if e.kind == "fail"]
    assert len(fails) == 4
    assert [c.tokens for c in out] == [c.tokens for c in ref]


# ---------------------------------------------------------------------------
# pipelined modeled clocks
# ---------------------------------------------------------------------------

def _two_unit_platform(overlap: bool = False,
                       tx_cost: float = 0.0) -> PlatformModel:
    pg = PlatformGraph("test-2u")
    pg.add_unit(ProcessingUnit("endpoint", "cpu", flops=1e9,
                               mem_bandwidth=1e9, tx_cost_per_byte=tx_cost))
    pg.add_unit(ProcessingUnit("server", "cpu", flops=4e9,
                               mem_bandwidth=4e9))
    pg.add_link(Link("endpoint", "server", bandwidth=100e6, latency_s=1e-4,
                     overlap=overlap))
    return PlatformModel(pg)


@pytest.fixture(scope="module")
def staged():
    cfg = _tiny_cfg(n_layers=4)
    params = T.init_params(cfg, KEY)
    g = T.to_actor_graph(cfg, params, batch=1, seq=8, group_size=2)
    names = list(g.actors)
    mapping = Mapping("half", {n: ("endpoint" if i < len(names) // 2
                                   else "server")
                               for i, n in enumerate(names)})
    return cfg, params, g, mapping


def test_pipelined_makespan_beats_sequential(staged):
    from repro.core import synthesize
    cfg, params, g, mapping = staged
    prog = synthesize(g, mapping)
    pm = _two_unit_platform(overlap=True)
    rng = np.random.RandomState(0)
    frames = [{"Input": jax.numpy.asarray(
        rng.randint(0, cfg.vocab_size, (1, 8)).astype(np.int32))}
        for _ in range(6)]
    sinks, sched = prog.run_pipelined(frames, platform=pm)
    assert len(sinks) == len(frames)
    # outputs identical to the non-pipelined staged execution
    ref = prog.run_local(frames[0])
    assert np.array_equal(np.asarray(sinks[0]["Head"]),
                          np.asarray(ref["Head"]))
    assert sched.makespan_s < sched.sequential_s
    # bottleneck lower bound: no schedule finishes before the busiest
    # unit has done all its frames
    assert sched.makespan_s >= max(sched.unit_busy_s.values()) - 1e-12
    # per-unit modeled clocks are monotone and causally consistent
    last = defaultdict(float)
    for e in sched.entries:
        assert e.finish_s >= e.start_s
        assert e.start_s >= last[e.unit] - 1e-12
        last[e.unit] = e.finish_s


@pytest.mark.parametrize("tx_cost", [0.0, 56e-9])
def test_simulator_concurrent_clocks_monotone(staged, tx_cost):
    """tx_cost > 0 covers the sender-side TX CPU charge: the sequential
    reference must include it or pipeline_speedup drops below 1."""
    cfg, params, g, mapping = staged
    pm = _two_unit_platform(overlap=False, tx_cost=tx_cost)
    rng = np.random.RandomState(0)
    feed = [jax.numpy.asarray(rng.randint(0, cfg.vocab_size, (1, 8))
                              .astype(np.int32)) for _ in range(5)]
    res = Simulator(g, mapping=mapping, platform=pm).run(
        len(feed), source_inputs={"Input": feed})
    assert res.modeled_makespan_s > 0
    # concurrency can only help: makespan within [bottleneck, sequential]
    assert res.modeled_makespan_s <= res.modeled_total_s() + 1e-12
    assert res.modeled_makespan_s >= max(res.unit_busy_s.values()) - 1e-12
    assert res.pipeline_speedup >= 1.0
    last = defaultdict(float)
    for f in res.firings:
        assert f.finish_s >= f.start_s - 1e-12
        assert f.start_s >= last[f.unit] - 1e-12
        last[f.unit] = f.finish_s


def test_simulator_single_unit_makespan_is_sequential():
    """Without a second unit there is nothing to overlap: the concurrent
    clocks must degenerate to the summed busy time."""
    from repro.models.cnn import vehicle_graph
    g = vehicle_graph()
    pg = PlatformGraph("one")
    pg.add_unit(ProcessingUnit("endpoint", "cpu", flops=1e9,
                               mem_bandwidth=1e9))
    mapping = Mapping("all-local", {n: "endpoint" for n in g.actors})
    res = Simulator(g, mapping=mapping,
                    platform=PlatformModel(pg)).run(3)
    assert res.modeled_makespan_s == pytest.approx(res.modeled_total_s())
