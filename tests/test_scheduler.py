"""Continuous-batching scheduler + pipelined execution tests:

* admission/eviction ordering — FIFO admission, slots freed on eviction
  and reused by later requests;
* KV-slot reuse correctness — the shared-slot decode batch emits exactly
  the static-bucket path's greedy tokens, across mixed prompt lengths,
  eos stops and slot churn;
* pipelined modeled clocks — per-unit start times are monotone, every
  firing respects data availability, and the pipelined makespan beats
  sequential execution of the same stages while staying >= the bottleneck
  bound.
"""
from __future__ import annotations

from collections import defaultdict

import jax
import numpy as np
import pytest

from repro.core import (Link, Mapping, PlatformGraph, PlatformModel,
                        ProcessingUnit, Simulator)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.scheduler import (ContinuousScheduler, SchedulerConfig,
                                     SlotFailure)
from repro.runtime.serving import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _tiny_cfg(n_layers: int = 2) -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=n_layers, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, KEY)


def _mixed_requests(cfg, specs, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=mnew)
            for i, (plen, mnew) in enumerate(specs)]


# ---------------------------------------------------------------------------
# KV-slot reuse correctness
# ---------------------------------------------------------------------------

def test_continuous_matches_static_bucket_tokens(setup):
    """More requests than slots, four distinct prompt lengths, varying
    decode lengths: the slot-reusing shared batch must emit the exact
    greedy tokens of the per-bucket baseline."""
    cfg, params = setup
    reqs = _mixed_requests(cfg, [(8, 6), (12, 4), (8, 9), (5, 1), (12, 7),
                                 (16, 5), (7, 3), (9, 8), (8, 2), (16, 6)])
    static = ServeEngine(cfg, params, max_len=64).generate(reqs)
    cont = ServeEngine(cfg, params, max_len=64, mode="continuous",
                       max_slots=4).generate(reqs)
    assert [c.id for c in cont] == [s.id for s in static]
    for s, c in zip(static, cont):
        assert c.tokens == s.tokens, f"request {s.id} diverged"


def test_continuous_respects_eos(setup):
    cfg, params = setup
    reqs = _mixed_requests(cfg, [(8, 12), (10, 12), (6, 12)])
    static = ServeEngine(cfg, params, max_len=64).generate(reqs)
    # pick an eos that actually occurs mid-stream for request 0
    eos = static[0].tokens[3]
    for r in reqs:
        r.eos = eos
    s2 = ServeEngine(cfg, params, max_len=64).generate(reqs)
    c2 = ServeEngine(cfg, params, max_len=64, mode="continuous",
                     max_slots=2).generate(reqs)
    assert [c.tokens for c in c2] == [s.tokens for s in s2]
    assert len(s2[0].tokens) < 12   # eos actually truncated


# ---------------------------------------------------------------------------
# admission / eviction ordering
# ---------------------------------------------------------------------------

def test_admission_is_fifo_and_eviction_frees_slots(setup):
    cfg, params = setup
    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=2, max_len=64))
    reqs = _mixed_requests(cfg, [(8, 2), (8, 6), (8, 3), (8, 4), (8, 1)])
    for r in reqs:
        sched.submit(r)
    outs = sched.run()
    assert [o.id for o in outs] == [0, 1, 2, 3, 4]
    admits = [e for e in sched.events if e.kind == "admit"]
    evicts = [e for e in sched.events if e.kind == "evict"]
    # FIFO: admission order == submission order even with slot contention
    assert [e.request_id for e in admits] == [0, 1, 2, 3, 4]
    assert len(evicts) == len(reqs)
    # every late admission reuses a slot somebody vacated first
    assert {e.slot for e in admits} == {0, 1}
    for a in admits[2:]:
        freed = [e for e in evicts if e.slot == a.slot and e.t_s <= a.t_s]
        assert freed, f"admission of {a.request_id} into occupied slot"
    # eviction happens exactly when the request's budget is spent
    for o in outs:
        assert len(o.tokens) == reqs[o.id].max_new_tokens


def test_overflowing_request_rejected(setup):
    cfg, params = setup
    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=2, max_len=16))
    with pytest.raises(ValueError, match="exceeds max_len"):
        sched.submit(Request(0, np.zeros(32, np.int32)))
    # prompt fits but prompt + decode budget would wrap the KV ring
    with pytest.raises(ValueError, match="exceeding max_len"):
        sched.submit(Request(1, np.zeros(14, np.int32), max_new_tokens=8))
    # exactly at capacity is fine: 14 + 3 - 1 == 16
    sched.submit(Request(2, np.zeros(14, np.int32), max_new_tokens=3))
    (out,) = sched.run()
    assert len(out.tokens) == 3


def test_static_path_rejects_overflow_identically(setup):
    """Both modes must agree on admission: a request the continuous
    scheduler rejects for KV-ring overflow can't silently wrap (and
    corrupt) on the static path either."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=16)
    with pytest.raises(ValueError, match="exceeding max_len"):
        eng.generate([Request(0, np.zeros(14, np.int32), max_new_tokens=8)])


def test_capped_cache_exempt_from_overflow_guard(setup):
    """max_cache_len caps the global-attention ring on purpose — the
    guard must not reject generations that slide past it."""
    cfg, params = setup
    import dataclasses
    capped = dataclasses.replace(cfg, max_cache_len=8)
    eng = ServeEngine(capped, params, max_len=16)
    outs = eng.generate([Request(0, np.zeros(8, np.int32),
                                 max_new_tokens=12)])
    assert len(outs[0].tokens) == 12


def test_arrivals_length_mismatch_rejected(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64, mode="continuous",
                      max_slots=2)
    reqs = _mixed_requests(cfg, [(8, 2), (8, 2)])
    with pytest.raises(ValueError, match="arrivals"):
        eng.generate(reqs, arrivals=[0.0])


def test_arrival_times_produce_waiting(setup):
    """A request arriving later must not be admitted before its arrival
    instant (open-loop Poisson workloads rely on this)."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64, mode="continuous",
                      max_slots=4)
    reqs = _mixed_requests(cfg, [(8, 4), (8, 4)])
    outs = eng.generate(reqs, arrivals=[0.0, 0.05])
    byid = {o.id: o for o in outs}
    assert byid[1].first_token_s >= 0.05
    assert byid[1].ttft_s >= 0.0


# ---------------------------------------------------------------------------
# failure injection: requests on failed slots are re-queued, not dropped
# ---------------------------------------------------------------------------

def test_slot_failure_requeues_not_drops(setup):
    """Mid-decode slot loss: affected requests go back to the head of the
    admission queue and re-prefill; every request (affected or not) must
    emit greedy tokens bit-identical to the failure-free run."""
    cfg, params = setup
    specs = [(8, 6), (12, 4), (8, 9), (5, 5), (12, 7)]
    ref_sched = ContinuousScheduler(cfg, params,
                                    SchedulerConfig(max_slots=2, max_len=64))
    for r in _mixed_requests(cfg, specs):
        ref_sched.submit(r)
    ref = ref_sched.run()

    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=2, max_len=64),
                                failures=[SlotFailure(step=3, slots=(0,))])
    for r in _mixed_requests(cfg, specs):
        sched.submit(r)
    out = sched.run()

    fails = [e for e in sched.events if e.kind == "fail"]
    assert fails, "injected failure never applied"
    assert [c.id for c in out] == [c.id for c in ref], "requests dropped"
    for a, b in zip(ref, out):
        assert a.tokens == b.tokens, f"request {a.id} diverged after requeue"
    # the victim was re-admitted (two admits), budget fully served
    victim = fails[0].request_id
    admits = [e.request_id for e in sched.events if e.kind == "admit"]
    assert admits.count(victim) == 2
    assert len(out[victim].tokens) == specs[victim][1]


def test_whole_unit_failure_requeues_every_active_request(setup):
    """slots=None models whole-unit loss: every active request re-queues
    in FIFO (arrival) order and the stream still completes bit-exactly."""
    cfg, params = setup
    specs = [(8, 5), (12, 5), (8, 5), (16, 5)]
    ref = ServeEngine(cfg, params, max_len=64).generate(
        _mixed_requests(cfg, specs))
    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=4, max_len=64),
                                failures=[SlotFailure(step=1)])
    for r in _mixed_requests(cfg, specs):
        sched.submit(r)
    out = sched.run()
    fails = [e for e in sched.events if e.kind == "fail"]
    assert len(fails) == 4
    assert [c.tokens for c in out] == [c.tokens for c in ref]


# ---------------------------------------------------------------------------
# pipelined modeled clocks
# ---------------------------------------------------------------------------

def _two_unit_platform(overlap: bool = False,
                       tx_cost: float = 0.0) -> PlatformModel:
    pg = PlatformGraph("test-2u")
    pg.add_unit(ProcessingUnit("endpoint", "cpu", flops=1e9,
                               mem_bandwidth=1e9, tx_cost_per_byte=tx_cost))
    pg.add_unit(ProcessingUnit("server", "cpu", flops=4e9,
                               mem_bandwidth=4e9))
    pg.add_link(Link("endpoint", "server", bandwidth=100e6, latency_s=1e-4,
                     overlap=overlap))
    return PlatformModel(pg)


@pytest.fixture(scope="module")
def staged():
    cfg = _tiny_cfg(n_layers=4)
    params = T.init_params(cfg, KEY)
    g = T.to_actor_graph(cfg, params, batch=1, seq=8, group_size=2)
    names = list(g.actors)
    mapping = Mapping("half", {n: ("endpoint" if i < len(names) // 2
                                   else "server")
                               for i, n in enumerate(names)})
    return cfg, params, g, mapping


def test_pipelined_makespan_beats_sequential(staged):
    from repro.core import synthesize
    cfg, params, g, mapping = staged
    prog = synthesize(g, mapping)
    pm = _two_unit_platform(overlap=True)
    rng = np.random.RandomState(0)
    frames = [{"Input": jax.numpy.asarray(
        rng.randint(0, cfg.vocab_size, (1, 8)).astype(np.int32))}
        for _ in range(6)]
    sinks, sched = prog.run_pipelined(frames, platform=pm)
    assert len(sinks) == len(frames)
    # outputs identical to the non-pipelined staged execution
    ref = prog.run_local(frames[0])
    assert np.array_equal(np.asarray(sinks[0]["Head"]),
                          np.asarray(ref["Head"]))
    assert sched.makespan_s < sched.sequential_s
    # bottleneck lower bound: no schedule finishes before the busiest
    # unit has done all its frames
    assert sched.makespan_s >= max(sched.unit_busy_s.values()) - 1e-12
    # per-unit modeled clocks are monotone and causally consistent
    last = defaultdict(float)
    for e in sched.entries:
        assert e.finish_s >= e.start_s
        assert e.start_s >= last[e.unit] - 1e-12
        last[e.unit] = e.finish_s


@pytest.mark.parametrize("tx_cost", [0.0, 56e-9])
def test_simulator_concurrent_clocks_monotone(staged, tx_cost):
    """tx_cost > 0 covers the sender-side TX CPU charge: the sequential
    reference must include it or pipeline_speedup drops below 1."""
    cfg, params, g, mapping = staged
    pm = _two_unit_platform(overlap=False, tx_cost=tx_cost)
    rng = np.random.RandomState(0)
    feed = [jax.numpy.asarray(rng.randint(0, cfg.vocab_size, (1, 8))
                              .astype(np.int32)) for _ in range(5)]
    res = Simulator(g, mapping=mapping, platform=pm).run(
        len(feed), source_inputs={"Input": feed})
    assert res.modeled_makespan_s > 0
    # concurrency can only help: makespan within [bottleneck, sequential]
    assert res.modeled_makespan_s <= res.modeled_total_s() + 1e-12
    assert res.modeled_makespan_s >= max(res.unit_busy_s.values()) - 1e-12
    assert res.pipeline_speedup >= 1.0
    last = defaultdict(float)
    for f in res.firings:
        assert f.finish_s >= f.start_s - 1e-12
        assert f.start_s >= last[f.unit] - 1e-12
        last[f.unit] = f.finish_s


def test_simulator_single_unit_makespan_is_sequential():
    """Without a second unit there is nothing to overlap: the concurrent
    clocks must degenerate to the summed busy time."""
    from repro.models.cnn import vehicle_graph
    g = vehicle_graph()
    pg = PlatformGraph("one")
    pg.add_unit(ProcessingUnit("endpoint", "cpu", flops=1e9,
                               mem_bandwidth=1e9))
    mapping = Mapping("all-local", {n: "endpoint" for n in g.actors})
    res = Simulator(g, mapping=mapping,
                    platform=PlatformModel(pg)).run(3)
    assert res.modeled_makespan_s == pytest.approx(res.modeled_total_s())
