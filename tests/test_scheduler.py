"""Continuous-batching scheduler + pipelined execution tests:

* admission/eviction ordering — FIFO admission, slots freed on eviction
  and reused by later requests;
* paged KV mechanics — admission waits instead of over-committing the
  pool, growth can preempt an in-flight chunked prefill, block
  accounting balances across evict/fail/preempt (token identity against
  the static oracle for every layout/policy combination lives in
  tests/test_conformance_matrix.py);
* prefix sharing — admissions with a common prompt prefix map the same
  physical blocks (observable refcounts), eviction releases references
  rather than freeing shared blocks, the prefix index dies with its
  blocks, and the copy-on-write growth guard gives a writer a private
  copy;
* pipelined modeled clocks — per-unit start times are monotone, every
  firing respects data availability, and the pipelined makespan beats
  sequential execution of the same stages while staying >= the bottleneck
  bound.
"""
from __future__ import annotations

from collections import defaultdict

import jax
import numpy as np
import pytest

from repro.core import (Link, Mapping, PlatformGraph, PlatformModel,
                        ProcessingUnit, Simulator)
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.scheduler import (ContinuousScheduler, SchedulerConfig,
                                     SlotFailure)
from repro.runtime.serving import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def _tiny_cfg(n_layers: int = 2) -> ModelConfig:
    return ModelConfig(
        name="tiny", arch_type="dense", n_layers=n_layers, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32",
        param_dtype="float32", attn_chunk=16, remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _tiny_cfg()
    return cfg, T.init_params(cfg, KEY)


def _mixed_requests(cfg, specs, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(i, rng.randint(0, cfg.vocab_size, plen).astype(np.int32),
                    max_new_tokens=mnew)
            for i, (plen, mnew) in enumerate(specs)]


# ---------------------------------------------------------------------------
# paged KV cache + chunked prefill
# (greedy-identity cells live in tests/test_conformance_matrix.py)
# ---------------------------------------------------------------------------

MIXED_SPECS = [(8, 6), (12, 4), (8, 9), (5, 1), (12, 7),
               (16, 5), (7, 3), (9, 8), (8, 2), (16, 6)]


def test_paged_admission_waits_when_pool_exhausted(setup):
    """A pool that fits ~one request's worst case at a time must
    serialize admissions (no over-commit) and still serve everything:
    the set of concurrently admitted requests never needs more blocks
    than the pool holds."""
    cfg, params = setup
    specs = [(8, 4)] * 5                 # worst case 11 rows -> 3 blocks
    sched = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=4, max_len=32, paged=True,
                                     block_size=4, num_blocks=5, debug=True))
    for r in _mixed_requests(cfg, specs):
        sched.submit(r)
    outs = sched.run()
    assert [len(o.tokens) for o in outs] == [m for _, m in specs]
    live = set()
    peak = 0
    for e in sched.events:
        if e.kind == "admit":
            live.add(e.request_id)
        elif e.kind in ("evict", "fail", "preempt"):
            live.discard(e.request_id)
        peak = max(peak, len(live))
    assert peak <= 2, f"over-committed pool: {peak} concurrent requests"
    assert sched.alloc.in_use == 0


def test_growth_can_preempt_inflight_chunked_prefill(setup):
    """A pool dried out partly by a half-prefilled prompt's blocks must
    still let an older request's decode growth make progress: the
    in-flight chunked prefill is a preemption candidate like any active
    slot, not an invisible block holder that crashes run()."""
    cfg, params = setup
    # capacity 7 blocks of 2 rows. Request 0 (2-row prompt, 12 new
    # tokens, worst case 7 blocks) is decoding and growing a block every
    # 2 steps while request 1's 10-row prompt (5 blocks, admitted
    # upfront) spends 5 iterations in 2-token prefill chunks — the pool
    # runs dry at request 0's second growth, mid-prefill.
    sched = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=2, max_len=14, paged=True,
                                     block_size=2, num_blocks=8,
                                     prefill_chunk=2, debug=True))
    rng = np.random.RandomState(0)
    sched.submit(Request(0, rng.randint(0, cfg.vocab_size, 2)
                         .astype(np.int32), max_new_tokens=12))
    sched.submit(Request(1, rng.randint(0, cfg.vocab_size, 10)
                         .astype(np.int32), max_new_tokens=2))
    outs = sched.run()
    assert [len(o.tokens) for o in outs] == [12, 2]
    preempted = [e for e in sched.events if e.kind == "preempt"]
    assert preempted and preempted[0].request_id == 1
    assert sched.alloc.in_use == 0


def test_paged_rejects_configs_with_no_global_attention(setup):
    """Subquadratic configs are exempt from the max_len rows bound, so
    paged growth could index past the block table; they also have no
    global-attn K/V to page. The combination is rejected up front."""
    cfg, params = setup
    import dataclasses
    local = dataclasses.replace(cfg, layer_pattern=("attn_local",), window=8)
    with pytest.raises(ValueError, match="paged KV cache pages"):
        ContinuousScheduler(local, params,
                            SchedulerConfig(max_slots=2, paged=True))


def test_evicted_slot_state_is_zeroed(setup):
    """No stale host-side mirrors after a drain: cache_len, last-token
    and block-table rows of freed slots are all zero (the invariant that
    used to rot silently when only cache_len was reset)."""
    cfg, params = setup
    sched = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=2, max_len=64, paged=True,
                                     block_size=8, debug=True),
        failures=[SlotFailure(step=2, slots=(1,))])
    for r in _mixed_requests(cfg, MIXED_SPECS[:5]):
        sched.submit(r)
    sched.run()
    assert not sched.cache_len.any()
    assert not sched.tokens.any()
    assert not sched.block_tables.any()
    assert sched.alloc.in_use == 0


def test_run_is_reentrant_and_keeps_pending_failures(setup):
    """A failure scheduled past the first drain's final step must fire in
    a later run() — the injected list is tracked with a cursor, not
    consumed destructively — and both drains stay bit-identical to the
    static path."""
    cfg, params = setup
    specs_a, specs_b = MIXED_SPECS[:3], MIXED_SPECS[3:6]
    static = ServeEngine(cfg, params, max_len=64).generate(
        _mixed_requests(cfg, specs_a + specs_b))
    sched = ContinuousScheduler(
        cfg, params, SchedulerConfig(max_slots=4, max_len=64, debug=True),
        failures=[SlotFailure(step=10 ** 6),    # never due: must survive
                  SlotFailure(step=12, slots=(0,))])
    reqs = _mixed_requests(cfg, specs_a + specs_b)
    for r in reqs[:3]:
        sched.submit(r)
    first = sched.run()
    steps_after_first = sched.step_count
    for r in reqs[3:]:
        sched.submit(r)
    second = sched.run()
    outs = sorted(first + second, key=lambda c: c.id)
    assert [c.tokens for c in outs] == [s.tokens for s in static]
    # the step-12 failure was consumed by whichever drain reached step 12
    # (the second, unless the first ran long), and the far-future one is
    # still pending — not dropped with the first drain's state
    assert steps_after_first < sched.step_count >= 12
    assert sched._failure_pos == 1
    assert sched.failures[sched._failure_pos].step == 10 ** 6


# ---------------------------------------------------------------------------
# admission / eviction ordering
# ---------------------------------------------------------------------------

def test_admission_is_fifo_and_eviction_frees_slots(setup):
    cfg, params = setup
    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=2, max_len=64))
    reqs = _mixed_requests(cfg, [(8, 2), (8, 6), (8, 3), (8, 4), (8, 1)])
    for r in reqs:
        sched.submit(r)
    outs = sched.run()
    assert [o.id for o in outs] == [0, 1, 2, 3, 4]
    admits = [e for e in sched.events if e.kind == "admit"]
    evicts = [e for e in sched.events if e.kind == "evict"]
    # FIFO: admission order == submission order even with slot contention
    assert [e.request_id for e in admits] == [0, 1, 2, 3, 4]
    assert len(evicts) == len(reqs)
    # every late admission reuses a slot somebody vacated first
    assert {e.slot for e in admits} == {0, 1}
    for a in admits[2:]:
        freed = [e for e in evicts if e.slot == a.slot and e.t_s <= a.t_s]
        assert freed, f"admission of {a.request_id} into occupied slot"
    # eviction happens exactly when the request's budget is spent
    for o in outs:
        assert len(o.tokens) == reqs[o.id].max_new_tokens


def test_overflowing_request_rejected(setup):
    cfg, params = setup
    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=2, max_len=16))
    with pytest.raises(ValueError, match="exceeds max_len"):
        sched.submit(Request(0, np.zeros(32, np.int32)))
    # prompt fits but prompt + decode budget would wrap the KV ring
    with pytest.raises(ValueError, match="exceeding max_len"):
        sched.submit(Request(1, np.zeros(14, np.int32), max_new_tokens=8))
    # exactly at capacity is fine: 14 + 3 - 1 == 16
    sched.submit(Request(2, np.zeros(14, np.int32), max_new_tokens=3))
    (out,) = sched.run()
    assert len(out.tokens) == 3


def test_static_path_rejects_overflow_identically(setup):
    """Both modes must agree on admission: a request the continuous
    scheduler rejects for KV-ring overflow can't silently wrap (and
    corrupt) on the static path either."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=16)
    with pytest.raises(ValueError, match="exceeding max_len"):
        eng.generate([Request(0, np.zeros(14, np.int32), max_new_tokens=8)])


def test_capped_cache_exempt_from_overflow_guard(setup):
    """max_cache_len caps the global-attention ring on purpose — the
    guard must not reject generations that slide past it."""
    cfg, params = setup
    import dataclasses
    capped = dataclasses.replace(cfg, max_cache_len=8)
    eng = ServeEngine(capped, params, max_len=16)
    outs = eng.generate([Request(0, np.zeros(8, np.int32),
                                 max_new_tokens=12)])
    assert len(outs[0].tokens) == 12


def test_arrivals_length_mismatch_rejected(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64, mode="continuous",
                      max_slots=2)
    reqs = _mixed_requests(cfg, [(8, 2), (8, 2)])
    with pytest.raises(ValueError, match="arrivals"):
        eng.generate(reqs, arrivals=[0.0])


def test_arrival_times_produce_waiting(setup):
    """A request arriving later must not be admitted before its arrival
    instant (open-loop Poisson workloads rely on this)."""
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_len=64, mode="continuous",
                      max_slots=4)
    reqs = _mixed_requests(cfg, [(8, 4), (8, 4)])
    outs = eng.generate(reqs, arrivals=[0.0, 0.05])
    byid = {o.id: o for o in outs}
    assert byid[1].first_token_s >= 0.05
    assert byid[1].ttft_s >= 0.0


# ---------------------------------------------------------------------------
# failure injection: requests on failed slots are re-queued, not dropped
# ---------------------------------------------------------------------------

def test_slot_failure_requeues_not_drops(setup):
    """Mid-decode slot loss: affected requests go back to the head of the
    admission queue and re-prefill; every request (affected or not) must
    emit greedy tokens bit-identical to the failure-free run."""
    cfg, params = setup
    specs = [(8, 6), (12, 4), (8, 9), (5, 5), (12, 7)]
    ref_sched = ContinuousScheduler(cfg, params,
                                    SchedulerConfig(max_slots=2, max_len=64))
    for r in _mixed_requests(cfg, specs):
        ref_sched.submit(r)
    ref = ref_sched.run()

    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=2, max_len=64),
                                failures=[SlotFailure(step=3, slots=(0,))])
    for r in _mixed_requests(cfg, specs):
        sched.submit(r)
    out = sched.run()

    fails = [e for e in sched.events if e.kind == "fail"]
    assert fails, "injected failure never applied"
    assert [c.id for c in out] == [c.id for c in ref], "requests dropped"
    for a, b in zip(ref, out):
        assert a.tokens == b.tokens, f"request {a.id} diverged after requeue"
    # the victim was re-admitted (two admits), budget fully served
    victim = fails[0].request_id
    admits = [e.request_id for e in sched.events if e.kind == "admit"]
    assert admits.count(victim) == 2
    assert len(out[victim].tokens) == specs[victim][1]


def test_whole_unit_failure_requeues_every_active_request(setup):
    """slots=None models whole-unit loss: every active request re-queues
    in FIFO (arrival) order and the stream still completes bit-exactly."""
    cfg, params = setup
    specs = [(8, 5), (12, 5), (8, 5), (16, 5)]
    ref = ServeEngine(cfg, params, max_len=64).generate(
        _mixed_requests(cfg, specs))
    sched = ContinuousScheduler(cfg, params,
                                SchedulerConfig(max_slots=4, max_len=64),
                                failures=[SlotFailure(step=1)])
    for r in _mixed_requests(cfg, specs):
        sched.submit(r)
    out = sched.run()
    fails = [e for e in sched.events if e.kind == "fail"]
    assert len(fails) == 4
    assert [c.tokens for c in out] == [c.tokens for c in ref]


# ---------------------------------------------------------------------------
# prefix sharing (paged copy-on-write)
# ---------------------------------------------------------------------------

def _prefix_sched(cfg, params, **kw):
    base = dict(max_slots=2, max_len=32, paged=True, block_size=4,
                prefix_cache=True, debug=True)
    base.update(kw)
    return ContinuousScheduler(cfg, params, SchedulerConfig(**base))


def test_prefix_sharing_maps_same_blocks(setup):
    """Two concurrently-admitted requests with a common prompt prefix
    share physical blocks: identical table entries for the matched
    pages, refcount 2 on each, and the matched rows never re-prefill."""
    cfg, params = setup
    rng = np.random.RandomState(0)
    head = rng.randint(0, cfg.vocab_size, 14).astype(np.int32)
    tail = rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
    sched = _prefix_sched(cfg, params)
    sched.submit(Request(0, head, max_new_tokens=6))
    sched.submit(Request(1, np.concatenate([head, tail]), max_new_tokens=6))
    sched.step_once()                   # one admission pass: 0 then 1
    s0, s1 = sched.block_tables[0], sched.block_tables[1]
    # request 1 matched request 0's whole prompt (14 rows: 3 full pages
    # shared, the partial tail seeded through the scratch)
    assert (s0[:3] == s1[:3]).all() and s0[:3].all(), (s0, s1)
    for blk in s1[:3]:
        assert sched.alloc.refcount(int(blk)) == 2
    assert s0[3] != s1[3], "partial tail block must be private (COW)"
    st = sched.stats()
    assert st["prefix_hits"] == 1
    assert st["prefill_tokens_saved"] == 14
    sched.run()
    assert sched.alloc.in_use == 0
    assert not sched.layout._prefix_full and not sched.layout._prefix_partial
    assert not sched.layout._block_keys, "index outlived its blocks"


def test_prefix_match_variants(setup):
    """The index matches what it may and nothing more: block-aligned
    chains, whole-prompt partial tails (capped at len-1 so admission
    still has logits to sample from), and no false positives on
    divergent or too-short prompts."""
    cfg, params = setup
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, 14).astype(np.int32)
    aligned = rng.randint(0, cfg.vocab_size, 16).astype(np.int32)
    sched = _prefix_sched(cfg, params)
    sched.submit(Request(0, prompt, max_new_tokens=8))
    sched.submit(Request(1, aligned, max_new_tokens=8))
    sched.step_once()                   # both admitted, still decoding
    lay = sched.layout
    # exact duplicate of the 14-token prompt: the partial tail entry is
    # as long as the whole prompt, so only the full chain matches (the
    # last token is always recomputed for its logits)
    src, matched = lay.match_prefix(prompt.copy())
    assert matched == 12 and len(src) == 3
    # exact duplicate of the block-aligned prompt: the final full block
    # covers the whole prompt, so the match caps at len - 1 and the
    # boundary block is seeded-from, never table-shared
    src, matched = lay.match_prefix(aligned.copy())
    assert matched == 15 and len(src) == 4
    # same aligned prefix, divergent tail: full blocks only
    div = np.concatenate([prompt[:12], (prompt[12:14] + 1) % cfg.vocab_size])
    src, matched = lay.match_prefix(div)
    assert matched == 12 and len(src) == 3
    # longer prompt continuing the resident one: chain + partial tail
    longer = np.concatenate([prompt, prompt[:5]])
    src, matched = lay.match_prefix(longer)
    assert matched == 14 and len(src) == 4
    # divergence inside the first block: no match
    bad = prompt.copy()
    bad[0] = (bad[0] + 1) % cfg.vocab_size
    assert lay.match_prefix(bad) == ([], 0)
    # a strict prefix of the resident prompt: full blocks only (partial
    # tails are keyed by the whole resident prompt)
    src, matched = lay.match_prefix(prompt[:13].copy())
    assert matched == 12 and len(src) == 3
    sched.run()


def test_eviction_releases_references_not_shared_blocks(setup):
    """Cancelling the request that *created* a shared chain must not
    free the blocks out from under the survivor: references release one
    by one, the block comes home only at refcount 0, and the survivor's
    tokens stay bit-identical to the static path."""
    cfg, params = setup
    rng = np.random.RandomState(2)
    head = rng.randint(0, cfg.vocab_size, 12).astype(np.int32)
    tail = rng.randint(0, cfg.vocab_size, 5).astype(np.int32)
    reqs = [Request(0, head, max_new_tokens=12),
            Request(1, np.concatenate([head, tail]), max_new_tokens=8)]
    static = ServeEngine(cfg, params, max_len=32).generate(
        [Request(r.id, r.prompt, max_new_tokens=r.max_new_tokens)
         for r in reqs])
    sched = _prefix_sched(cfg, params)
    t0 = sched.submit(reqs[0])
    sched.submit(reqs[1])
    sched.step_once()
    shared = [int(b) for b in sched.block_tables[1][:3]]
    assert all(sched.alloc.refcount(b) == 2 for b in shared)
    sched.request_cancel(t0)            # creator goes away mid-decode
    sched.step_once()
    assert all(sched.alloc.refcount(b) == 1 for b in shared), \
        "survivor lost its shared blocks"
    outs = {c.id: c for c in sched.run()}
    assert outs[1].tokens == static[1].tokens
    assert sched.alloc.in_use == 0


def test_grow_one_copy_on_write_gives_private_copy(setup):
    """The defensive COW guard on decode growth: a write targeting a
    block with refcount > 1 allocates a fresh block, copies the rows,
    swaps the table entry and drops one reference — the original block
    and its other reader are untouched."""
    cfg, params = setup
    rng = np.random.RandomState(3)
    sched = _prefix_sched(cfg, params)
    sched.submit(Request(0, rng.randint(0, cfg.vocab_size, 8)
                         .astype(np.int32), max_new_tokens=4))
    sched.step_once()
    lay = sched.layout
    old = int(sched.block_tables[0][1])
    lay.alloc.share([old])              # simulate a second reader
    assert lay.needs_block(0, 5)        # pos 5 -> page 1, shared
    assert lay.grow_one(0, 5)
    new = int(sched.block_tables[0][1])
    assert new != old
    assert lay.alloc.refcount(old) == 1 and lay.alloc.refcount(new) == 1
    k = np.asarray(lay.cache["scan"][0]["k"])
    assert np.array_equal(k[:, old], k[:, new]), "COW did not copy rows"
    assert old not in lay._slot_blocks[0] and new in lay._slot_blocks[0]
    lay._unregister(lay.alloc.release([old]))   # drop the simulated reader
    outs = sched.run()
    assert len(outs[0].tokens) == 4
    assert sched.alloc.in_use == 0


def test_prefix_seed_with_non_block_multiple_max_len(setup):
    """max_len is rounded up to a whole number of blocks in paged mode,
    so seeding a matched prefix whole-pages-at-a-time always fits the
    scratch cache — even when the configured max_len isn't a block
    multiple and the match ends mid-page (the near-miss shape: pages *
    block_size > configured max_len)."""
    cfg, params = setup
    rng = np.random.RandomState(5)
    head = rng.randint(0, cfg.vocab_size, 18).astype(np.int32)
    sched = _prefix_sched(cfg, params, max_len=20, block_size=8,
                          max_slots=2)
    assert sched.max_len == 24          # rounded up from 20
    static = ServeEngine(cfg, params, max_len=24).generate(
        [Request(0, head, max_new_tokens=2),
         Request(1, np.concatenate([head, head[:1]]), max_new_tokens=2)])
    sched.submit(Request(0, head, max_new_tokens=2))
    # 19-token prompt matching all 18 resident rows: seeds ceil(18/8)=3
    # whole pages = 24 rows, exactly the rounded scratch length
    sched.submit(Request(1, np.concatenate([head, head[:1]]),
                         max_new_tokens=2))
    outs = sched.run()
    assert [c.tokens for c in outs] == [s.tokens for s in static]
    assert sched.stats()["prefix_hits"] == 1
    assert sched.alloc.in_use == 0


def test_prefix_cache_silently_disabled_without_extend_support(setup):
    """Configs outside supports_chunked_prefill can't resume mid-prompt;
    prefix_cache degrades to plain paged serving instead of erroring
    (mirroring prefill_chunk's fallback)."""
    cfg, params = setup
    import dataclasses
    mixed = dataclasses.replace(cfg, layer_pattern=("attn", "attn_local"),
                                window=8)
    mixed_params = T.init_params(mixed, KEY)
    sched = ContinuousScheduler(
        mixed, mixed_params,
        SchedulerConfig(max_slots=2, max_len=32, paged=True, block_size=4,
                        prefix_cache=True, debug=True))
    assert not sched.layout.prefix_cache
    rng = np.random.RandomState(4)
    head = rng.randint(0, mixed.vocab_size, 8).astype(np.int32)
    for i in range(2):
        sched.submit(Request(i, head.copy(), max_new_tokens=3))
    outs = sched.run()
    assert [len(o.tokens) for o in outs] == [3, 3]
    assert sched.stats()["prefix_hits"] == 0


# ---------------------------------------------------------------------------
# pipelined modeled clocks
# ---------------------------------------------------------------------------

def _two_unit_platform(overlap: bool = False,
                       tx_cost: float = 0.0) -> PlatformModel:
    pg = PlatformGraph("test-2u")
    pg.add_unit(ProcessingUnit("endpoint", "cpu", flops=1e9,
                               mem_bandwidth=1e9, tx_cost_per_byte=tx_cost))
    pg.add_unit(ProcessingUnit("server", "cpu", flops=4e9,
                               mem_bandwidth=4e9))
    pg.add_link(Link("endpoint", "server", bandwidth=100e6, latency_s=1e-4,
                     overlap=overlap))
    return PlatformModel(pg)


@pytest.fixture(scope="module")
def staged():
    cfg = _tiny_cfg(n_layers=4)
    params = T.init_params(cfg, KEY)
    g = T.to_actor_graph(cfg, params, batch=1, seq=8, group_size=2)
    names = list(g.actors)
    mapping = Mapping("half", {n: ("endpoint" if i < len(names) // 2
                                   else "server")
                               for i, n in enumerate(names)})
    return cfg, params, g, mapping


def test_pipelined_makespan_beats_sequential(staged):
    from repro.core import synthesize
    cfg, params, g, mapping = staged
    prog = synthesize(g, mapping)
    pm = _two_unit_platform(overlap=True)
    rng = np.random.RandomState(0)
    frames = [{"Input": jax.numpy.asarray(
        rng.randint(0, cfg.vocab_size, (1, 8)).astype(np.int32))}
        for _ in range(6)]
    sinks, sched = prog.run_pipelined(frames, platform=pm)
    assert len(sinks) == len(frames)
    # outputs identical to the non-pipelined staged execution
    ref = prog.run_local(frames[0])
    assert np.array_equal(np.asarray(sinks[0]["Head"]),
                          np.asarray(ref["Head"]))
    assert sched.makespan_s < sched.sequential_s
    # bottleneck lower bound: no schedule finishes before the busiest
    # unit has done all its frames
    assert sched.makespan_s >= max(sched.unit_busy_s.values()) - 1e-12
    # per-unit modeled clocks are monotone and causally consistent
    last = defaultdict(float)
    for e in sched.entries:
        assert e.finish_s >= e.start_s
        assert e.start_s >= last[e.unit] - 1e-12
        last[e.unit] = e.finish_s


@pytest.mark.parametrize("tx_cost", [0.0, 56e-9])
def test_simulator_concurrent_clocks_monotone(staged, tx_cost):
    """tx_cost > 0 covers the sender-side TX CPU charge: the sequential
    reference must include it or pipeline_speedup drops below 1."""
    cfg, params, g, mapping = staged
    pm = _two_unit_platform(overlap=False, tx_cost=tx_cost)
    rng = np.random.RandomState(0)
    feed = [jax.numpy.asarray(rng.randint(0, cfg.vocab_size, (1, 8))
                              .astype(np.int32)) for _ in range(5)]
    res = Simulator(g, mapping=mapping, platform=pm).run(
        len(feed), source_inputs={"Input": feed})
    assert res.modeled_makespan_s > 0
    # concurrency can only help: makespan within [bottleneck, sequential]
    assert res.modeled_makespan_s <= res.modeled_total_s() + 1e-12
    assert res.modeled_makespan_s >= max(res.unit_busy_s.values()) - 1e-12
    assert res.pipeline_speedup >= 1.0
    last = defaultdict(float)
    for f in res.firings:
        assert f.finish_s >= f.start_s - 1e-12
        assert f.start_s >= last[f.unit] - 1e-12
        last[f.unit] = f.finish_s


def test_simulator_single_unit_makespan_is_sequential():
    """Without a second unit there is nothing to overlap: the concurrent
    clocks must degenerate to the summed busy time."""
    from repro.models.cnn import vehicle_graph
    g = vehicle_graph()
    pg = PlatformGraph("one")
    pg.add_unit(ProcessingUnit("endpoint", "cpu", flops=1e9,
                               mem_bandwidth=1e9))
    mapping = Mapping("all-local", {n: "endpoint" for n in g.actors})
    res = Simulator(g, mapping=mapping,
                    platform=PlatformModel(pg)).run(3)
    assert res.modeled_makespan_s == pytest.approx(res.modeled_total_s())
