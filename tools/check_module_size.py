#!/usr/bin/env python
"""Module-size lint: keep the runtime package decomposed.

The scheduler started life as one 1,700-line monolith and was split
into ``runtime/scheduler/`` (types / allocator / layouts / prefill /
units / core) precisely so no single module re-accretes everything.
This lint is the ratchet: it fails the fast CI lane the moment any
module under ``src/repro/runtime/`` crosses the line budget, so growth
has to land as a new module (or a real refactor) instead of another
hundred lines on the biggest file.

Usage::

    python tools/check_module_size.py [--root src/repro/runtime] \
        [--limit 900] [-v]

Exits non-zero listing every offender; ``-v`` also prints the largest
modules while they still fit (the early-warning view).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

DEFAULT_ROOT = "src/repro/runtime"
DEFAULT_LIMIT = 900


def measure(root: Path) -> list:
    """(lines, path) per python module under ``root``, largest first."""
    sizes = []
    for p in sorted(root.rglob("*.py")):
        with open(p, "rb") as fh:
            sizes.append((sum(1 for _ in fh), p))
    return sorted(sizes, reverse=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if any runtime module exceeds the line budget")
    ap.add_argument("--root", default=DEFAULT_ROOT,
                    help=f"package directory to lint (default {DEFAULT_ROOT})")
    ap.add_argument("--limit", type=int, default=DEFAULT_LIMIT,
                    help="line budget per module (default "
                         f"{DEFAULT_LIMIT}; lower it to ratchet, never "
                         "raise it)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print the largest in-budget modules")
    args = ap.parse_args(argv)
    root = Path(args.root)
    if not root.is_dir():
        print(f"check_module_size: no such directory: {root}",
              file=sys.stderr)
        return 2
    sizes = measure(root)
    over = [(n, p) for n, p in sizes if n > args.limit]
    for n, p in over:
        print(f"FAIL {p}: {n} lines > {args.limit} — split it "
              f"(see src/repro/runtime/scheduler/ for the shape)",
              file=sys.stderr)
    if args.verbose or over:
        shown = over if over else sizes[:5]
        if not over:
            for n, p in shown:
                print(f"  ok {p}: {n}/{args.limit} lines")
    if not over:
        top = sizes[0] if sizes else (0, root)
        print(f"check_module_size: {len(sizes)} modules under {root} "
              f"within {args.limit} lines (largest: {top[1]} at "
              f"{top[0]})")
    return 1 if over else 0


if __name__ == "__main__":
    sys.exit(main())
